"""End-to-end training driver: a ~100M-param llama-family model for a few
hundred steps on CPU, with checkpointing, failure injection, and restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    PYTHONPATH=src python examples/train_lm.py --steps 50 --fail-at 20
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.models import lm
from repro.runtime.data import DataConfig, SyntheticDataset
from repro.runtime.elastic import SupervisorConfig, TrainSupervisor
from repro.runtime.optimizer import OptConfig, init_opt
from repro.runtime.train import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--grad-compress", action="store_true")
    args = ap.parse_args()

    # ~100M-param member of the chosen family (CPU-trainable)
    cfg = dataclasses.replace(
        get_config(args.arch, smoke=True),
        n_layers=8, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2304, vocab=16384, name=args.arch + "-100m")
    n = cfg.param_count()
    print(f"arch={cfg.name} params~{n/1e6:.0f}M steps={args.steps}")

    opt_cfg = OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps,
                        grad_compress=args.grad_compress)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt(params, opt_cfg)
    ds = SyntheticDataset(DataConfig(vocab=cfg.vocab, seq=args.seq,
                                     global_batch=args.batch, seed=0))
    step = jax.jit(make_train_step(cfg, opt_cfg))

    sup = TrainSupervisor(
        SupervisorConfig(ckpt_dir=args.ckpt_dir, ckpt_every=25),
        (params, opt), ds, step)
    t0 = time.time()
    fail = {args.fail_at} if args.fail_at is not None else None
    sup.run(args.steps, fail_at=fail)
    dt = time.time() - t0
    losses = [l for _, l in sup.metrics_log]
    print(f"loss: first={losses[0]:.3f} last={losses[-1]:.3f} "
          f"min={min(losses):.3f}")
    print(f"restarts={sup.restarts} wall={dt:.0f}s "
          f"({dt/max(len(losses),1)*1e3:.0f} ms/step)")
    assert losses[-1] < losses[0], "training did not reduce loss"


if __name__ == "__main__":
    main()
