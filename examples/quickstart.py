"""Quickstart: the STrack transport as a composable JAX module.

Simulates a 32->1 incast entirely inside one jitted lax.scan and prints the
paper's headline behaviours (fast convergence, queue pinned at target,
drops confined to the first RTT, fairness). Runtime: ~10s on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.sim.jaxsim import IncastConfig, run_incast


def main():
    cfg = IncastConfig(n_flows=32, msg_bytes=2 * 2 ** 20)
    print(f"STrack incast: {cfg.n_flows} flows x "
          f"{cfg.msg_bytes/2**20:.0f} MB over one 400G bottleneck")
    final, m = run_incast(cfg, n_ticks=30000)

    q = np.asarray(m["queue_pkts"]).astype(float)
    done = np.asarray(m["done"])
    drops = np.asarray(m["drops"])
    tick = m["tick_us"]
    target = m["target_qdelay_pkts"]

    busy = np.nonzero(done < cfg.n_flows)[0]
    steady = q[busy[len(busy) // 2]:busy[-1]] if len(busy) else q
    d = np.asarray(m["delivered"])[-1]
    jain = d.sum() ** 2 / (len(d) * np.sum(d * d))

    print(f"  flows finished:        {done[-1]}/{cfg.n_flows}")
    print(f"  drops (total):         {drops[-1]}  "
          f"(by 2 base-RTTs: {drops[min(250, len(drops)-1)]})")
    print(f"  steady queue median:   {np.median(steady):.0f} pkts "
          f"(target {target:.0f} pkts = {target*tick:.1f} us)")
    print(f"  Jain fairness index:   {jain:.4f}")
    print(f"  simulated time:        {len(q)*tick/1e3:.2f} ms "
          f"in one XLA program")


if __name__ == "__main__":
    main()
