"""Serving example: batched prefill + greedy decode with a KV cache
(the ``decode_*`` path of the dry-run), on a small model.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --new 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import lm
from repro.runtime.serve import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, T = args.batch, args.prompt_len
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg))

    cache_len = T + args.new
    cache = lm.init_cache(cfg, B, cache_len)
    # teacher-forced prompt consumption fills the cache
    tok = prompt[:, :1]
    t0 = time.time()
    for t in range(T):
        logits, cache = decode(params, cache, prompt[:, t:t + 1],
                               jnp.asarray(t, jnp.int32))
    t_prefill = time.time() - t0

    out = []
    t0 = time.time()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for t in range(T, T + args.new):
        out.append(tok)
        logits, cache = decode(params, cache, tok, jnp.asarray(t, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t_decode = time.time() - t0
    gen = jnp.concatenate(out, axis=1)

    # batched one-shot prefill (the prefill_32k path) must agree with the
    # incremental fill on the last-token logits
    pre_logits = prefill(params, {"tokens": prompt})
    print(f"arch={cfg.name} batch={B}")
    print(f"prompt fill:  {t_prefill/T*1e3:.1f} ms/token")
    print(f"decode:       {t_decode/args.new*1e3:.1f} ms/token")
    print(f"generated ids[0,:10]: {list(map(int, gen[0,:10]))}")
    print(f"prefill/decode last-logit max delta: "
          f"{float(jnp.abs(pre_logits - logits).max()):.3f} (pre-decode)")


if __name__ == "__main__":
    main()
