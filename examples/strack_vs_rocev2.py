"""The paper's headline comparison on the event-driven simulator:
permutation + incast + one collective, STrack vs RoCEv2.

    PYTHONPATH=src python examples/strack_vs_rocev2.py
"""
from repro.collective.algorithms import multi_job
from repro.core.params import NetworkSpec
from repro.sim.events import NetSim
from repro.sim.topology import full_bisection
from repro.sim.workloads import TraceRunner, run_incast, run_permutation


def main():
    net = NetworkSpec(link_gbps=400.0)
    topo_kw = dict(n_tor=4, hosts_per_tor=4)

    print("== permutation, 16 hosts, 2MB messages ==")
    res = {}
    for tr, kw in [("strack", {}), ("strack-oblivious",
                                    dict(oblivious_spray=True)),
                   ("roce", {})]:
        sim = NetSim(full_bisection(**topo_kw), net,
                     transport="roce" if tr == "roce" else "strack", **kw)
        r = run_permutation(sim, 2 * 2 ** 20, until=1e6)
        res[tr] = r["max_fct"]
        print(f"  {tr:18s} max FCT = {r['max_fct']:8.1f} us   "
              f"drops={r['drops']} pauses={r['pauses']}")
    print(f"  -> STrack speedup vs RoCEv2: "
          f"{res['roce']/res['strack']:.2f}x "
          f"(paper: up to 6.3x at 8K hosts)")

    print("== incast 8->1, 512KB ==")
    for tr in ("strack", "roce"):
        sim = NetSim(full_bisection(**topo_kw), net, transport=tr)
        r = run_incast(sim, 8, 512 * 2 ** 10, until=2e6)
        print(f"  {tr:18s} max FCT = {r['max_fct']:8.1f} us   "
              f"drops={r['drops']} pauses={r['pauses']}")
    print("  -> lossy STrack ~ lossless RoCEv2 (paper Fig 19 parity)")

    print("== 2 x DBT all-reduce (1MB), 16 hosts ==")
    for tr in ("strack", "roce"):
        sim = NetSim(full_bisection(**topo_kw), net, transport=tr)
        msgs, placement = multi_job("dbt", 2, 8, 16, 1 * 2 ** 20)
        r = TraceRunner(sim, msgs, placement).run(until=1e7)
        print(f"  {tr:18s} max collective = "
              f"{r['max_collective_time']:8.1f} us "
              f"({r['finished_groups']}/{r['total_groups']} done)")


if __name__ == "__main__":
    main()
