"""The paper's headline comparison: permutation + incast + one collective,
STrack vs RoCEv2.

BOTH legs run on the jitted multi-queue fat-tree fabric — STrack (adaptive
and oblivious spray, lossy) and the RoCEv2 baseline (DCQCN + go-back-N,
lossless via the fabric's PFC pause model) — one XLA program per run, over
identical scenario objects.  Only the dependency-scheduled collective trace
at the end still uses the event-driven oracle.

    PYTHONPATH=src python examples/strack_vs_rocev2.py
"""
from repro.collective.algorithms import multi_job
from repro.core.params import NetworkSpec
from repro.sim.events import NetSim
from repro.sim.topology import full_bisection
from repro.sim.workloads import (TraceRunner, incast_scenario,
                                 permutation_scenario, run_on_fabric)


def main():
    net = NetworkSpec(link_gbps=400.0)
    topo_kw = dict(n_tor=4, hosts_per_tor=4)
    topo = full_bisection(**topo_kw)

    print("== permutation, 16 hosts, 2MB messages ==")
    sc = permutation_scenario(topo, 2 * 2 ** 20, net=net)
    res = {}
    for tr, runner in [
            ("strack", lambda: run_on_fabric(sc, lb_mode="adaptive")),
            ("strack-oblivious",
             lambda: run_on_fabric(sc, lb_mode="oblivious")),
            ("roce", lambda: run_on_fabric(sc, protocol="rocev2"))]:
        r = runner()
        res[tr] = r["max_fct"]
        print(f"  {tr:18s} max FCT = {r['max_fct']:8.1f} us   "
              f"drops={r['drops']} pauses={r['pauses']} "
              f"[{r['backend']}]")
    print(f"  -> STrack speedup vs RoCEv2: "
          f"{res['roce']/res['strack']:.2f}x "
          f"(paper: up to 6.3x at 8K hosts)")

    print("== incast 8->1, 512KB ==")
    sc = incast_scenario(topo, 8, 512 * 2 ** 10, net=net)
    for tr, runner in [
            ("strack", lambda: run_on_fabric(sc)),
            ("roce", lambda: run_on_fabric(sc, protocol="rocev2"))]:
        r = runner()
        print(f"  {tr:18s} max FCT = {r['max_fct']:8.1f} us   "
              f"drops={r['drops']} pauses={r['pauses']} "
              f"[{r['backend']}]")
    print("  -> lossy STrack ~ lossless RoCEv2 (paper Fig 19 parity)")

    print("== 2 x DBT all-reduce (1MB), 16 hosts ==")
    for tr in ("strack", "roce"):
        sim = NetSim(full_bisection(**topo_kw), net, transport=tr)
        msgs, placement = multi_job("dbt", 2, 8, 16, 1 * 2 ** 20)
        r = TraceRunner(sim, msgs, placement).run(until=1e7)
        print(f"  {tr:18s} max collective = "
              f"{r['max_collective_time']:8.1f} us "
              f"({r['finished_groups']}/{r['total_groups']} done)")


if __name__ == "__main__":
    main()
