"""The paper's headline comparison: permutation + incast + one collective,
STrack vs RoCEv2.

EVERY leg — permutation, incast AND the dependency-scheduled DBT allreduce
collective — runs on the jitted multi-queue fat-tree fabric through the one
experiment API: ``run(scenario, RunConfig(...))``.  STrack runs adaptive /
oblivious spray (lossy); RoCEv2 runs DCQCN + go-back-N (lossless via the
fabric's PFC pause model), plus the tuned 4-QP striped variant
(``subflows=4``) on the collective.

    PYTHONPATH=src python examples/strack_vs_rocev2.py
"""
from repro.core.params import NetworkSpec
from repro.sim.topology import full_bisection
from repro.sim.workloads import (RunConfig, collective_scenario,
                                 incast_scenario, permutation_scenario, run)


def main():
    net = NetworkSpec(link_gbps=400.0)
    topo = full_bisection(4, 4)

    print("== permutation, 16 hosts, 2MB messages ==")
    sc = permutation_scenario(topo, 2 * 2 ** 20, net=net)
    res = {}
    for tr, cfg in [
            ("strack", RunConfig(lb_mode="adaptive")),
            ("strack-oblivious", RunConfig(lb_mode="oblivious")),
            ("roce", RunConfig(protocol="rocev2"))]:
        r = run(sc, cfg)
        res[tr] = r["max_fct"]
        print(f"  {tr:18s} max FCT = {r['max_fct']:8.1f} us   "
              f"drops={r['drops']} pauses={r['pauses']} "
              f"[{r['backend']}]")
    print(f"  -> STrack speedup vs RoCEv2: "
          f"{res['roce']/res['strack']:.2f}x "
          f"(paper: up to 6.3x at 8K hosts)")

    print("== incast 8->1, 512KB ==")
    sc = incast_scenario(topo, 8, 512 * 2 ** 10, net=net)
    for tr, cfg in [("strack", RunConfig()),
                    ("roce", RunConfig(protocol="rocev2"))]:
        r = run(sc, cfg)
        print(f"  {tr:18s} max FCT = {r['max_fct']:8.1f} us   "
              f"drops={r['drops']} pauses={r['pauses']} "
              f"[{r['backend']}]")
    print("  -> lossy STrack ~ lossless RoCEv2 (paper Fig 19 parity)")

    print("== 2 x DBT all-reduce (1MB), 16 hosts ==")
    sc = collective_scenario(topo, "dbt", 2, 8, 1 * 2 ** 20, net=net)
    for tr, cfg in [
            ("strack", RunConfig()),
            ("roce", RunConfig(protocol="rocev2")),
            ("roce-4qp", RunConfig(protocol="rocev2", subflows=4))]:
        r = run(sc, cfg)
        print(f"  {tr:18s} max collective = "
              f"{r['max_collective_time']:8.1f} us "
              f"({r['finished_groups']}/{r['total_groups']} done) "
              f"[{r['backend']}]")


if __name__ == "__main__":
    main()
