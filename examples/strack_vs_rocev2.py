"""The paper's headline comparison: permutation + incast + one collective,
STrack vs RoCEv2.

STrack (adaptive and oblivious spray) runs on the jitted multi-queue
fat-tree fabric — one XLA program per run; the RoCEv2 baseline runs on the
event-driven oracle (PFC/go-back-N live there).  Both backends consume the
same scenario objects, so the flows and topology are identical.

    PYTHONPATH=src python examples/strack_vs_rocev2.py
"""
from repro.collective.algorithms import multi_job
from repro.core.params import NetworkSpec
from repro.sim.events import NetSim
from repro.sim.topology import full_bisection
from repro.sim.workloads import (TraceRunner, incast_scenario,
                                 permutation_scenario, run_on_events,
                                 run_on_fabric)


def main():
    net = NetworkSpec(link_gbps=400.0)
    topo_kw = dict(n_tor=4, hosts_per_tor=4)
    topo = full_bisection(**topo_kw)

    print("== permutation, 16 hosts, 2MB messages ==")
    sc = permutation_scenario(topo, 2 * 2 ** 20, net=net)
    res = {}
    for tr, runner in [
            ("strack", lambda: run_on_fabric(sc, lb_mode="adaptive")),
            ("strack-oblivious",
             lambda: run_on_fabric(sc, lb_mode="oblivious")),
            ("roce", lambda: run_on_events(sc, transport="roce",
                                           until=1e6))]:
        r = runner()
        res[tr] = r["max_fct"]
        print(f"  {tr:18s} max FCT = {r['max_fct']:8.1f} us   "
              f"drops={r['drops']} pauses={r['pauses']} "
              f"[{r['backend']}]")
    print(f"  -> STrack speedup vs RoCEv2: "
          f"{res['roce']/res['strack']:.2f}x "
          f"(paper: up to 6.3x at 8K hosts)")

    print("== incast 8->1, 512KB ==")
    sc = incast_scenario(topo, 8, 512 * 2 ** 10, net=net)
    for tr, runner in [
            ("strack", lambda: run_on_fabric(sc)),
            ("roce", lambda: run_on_events(sc, transport="roce",
                                           until=2e6))]:
        r = runner()
        print(f"  {tr:18s} max FCT = {r['max_fct']:8.1f} us   "
              f"drops={r['drops']} pauses={r['pauses']} "
              f"[{r['backend']}]")
    print("  -> lossy STrack ~ lossless RoCEv2 (paper Fig 19 parity)")

    print("== 2 x DBT all-reduce (1MB), 16 hosts ==")
    for tr in ("strack", "roce"):
        sim = NetSim(full_bisection(**topo_kw), net, transport=tr)
        msgs, placement = multi_job("dbt", 2, 8, 16, 1 * 2 ** 20)
        r = TraceRunner(sim, msgs, placement).run(until=1e7)
        print(f"  {tr:18s} max collective = "
              f"{r['max_collective_time']:8.1f} us "
              f"({r['finished_groups']}/{r['total_groups']} done)")


if __name__ == "__main__":
    main()
