"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``us_per_call`` is the
simulated metric (max FCT / collective time in us); ``derived`` carries the
paper-claim validation (speedups, parity ratios, queue stability).

Every figure is driven through the ONE experiment API
(``repro.sim.workloads.run(scenario, RunConfig(...))``) — one command
reproduces the whole evaluation matrix on the jitted fabric, collectives
and 4-QP striped RoCEv2 included.

Full-scale variants of each figure are available via the per-module mains
(e.g. ``python -m benchmarks.permutation --full``).
"""
from __future__ import annotations

import sys

MIGRATION_TABLE = """\
old entry point (REMOVED in PR 8)              -> unified API call
----------------------------------------------------------------------------
run_on_fabric(sc, protocol=, lb_mode=, ...)    -> run(sc, RunConfig(backend="fabric", protocol=, lb_mode=, ...))
run_seed_sweep_on_fabric(scs, ...)             -> sweep(scs, RunConfig(...))
run_on_events(sc, transport="roce", ...)       -> run(sc, RunConfig(backend="events", protocol="rocev2", ...))
TraceRunner(sim, msgs, placement).run()        -> run(collective_scenario(...), RunConfig(...))
run_permutation(sim, msg)                      -> run(permutation_scenario(topo, msg), RunConfig(backend="events"))
run_incast(sim, fan_in, msg)                   -> run(incast_scenario(topo, fan_in, msg), RunConfig(backend="events"))
NetSim(..., roce_params=make_roce_params(net,
       qps_per_conn=4)) [4-QP striping]        -> run(sc, RunConfig(protocol="rocev2", subflows=4))

Prebuilt-sim runs (custom oracle wiring such as queue logs or link
failures) use run_scenario_on_sim(sim, scenario, until=...).
See docs/experiments.md for the full guide."""


def main() -> None:
    import argparse
    ap = argparse.ArgumentParser(
        description=__doc__,
        epilog="Migration from the legacy entry points:\n\n"
               + MIGRATION_TABLE,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.parse_args()
    from . import permutation, oversub_linkdown, incast, collectives
    rows = []
    print("name,us_per_call,derived")

    def emit(name, us, derived):
        print(f"{name},{us if us is not None else float('nan'):.1f},{derived}")
        sys.stdout.flush()

    # Figs 9-11: permutation across link speeds
    for gbps in (200.0, 400.0, 800.0):
        rs = permutation.run(link_gbps=gbps)
        for r in rs:
            if r["transport"] == "strack" or "speedup_vs_roce" not in r:
                continue
            emit(f"fig9_perm_{int(gbps)}G_msg{r['msg']//1024}K_{r['transport']}",
                 r["max_fct_us"],
                 f"strack_speedup={r['speedup_vs_roce']:.2f}x;"
                 f"adaptive_vs_obl={r.get('adaptive_vs_oblivious', 1):.2f}x")

    # Fig 8: queue settling, from the fabric's per-tick queue-depth traces
    # (both protocols on the fast path; settle = last time any queue's
    # depth-derived delay exceeded the base-RTT-scale threshold)
    rs = permutation.run(msg_sizes=[2 * 2 ** 20], trace_queues=True,
                         backend="fabric")
    for r in rs:
        if r["backend"] != "fabric":
            continue  # roce4 (oracle) logs a different settle metric
        emit(f"fig8_settle_{r['transport']}", r["max_fct_us"],
             f"last_qdelay_over_baseRTT_at_us={r['queue_settle_us']}")

    # Figs 12-15: oversubscription + link failures
    for r in oversub_linkdown.run_oversub(4) + oversub_linkdown.run_oversub(8):
        emit(f"fig12_{r['workload']}_{r['transport']}", r["max_fct_us"],
             f"speedup={r.get('speedup_vs_roce', '')}")
    for r in (oversub_linkdown.run_linkdown(0.0625)
              + oversub_linkdown.run_linkdown(0.25)):
        emit(f"fig14_{r['workload']}_{r['transport']}", r["max_fct_us"],
             f"speedup={r.get('speedup_vs_roce', '')};"
             f"adaptive_vs_obl={r.get('adaptive_vs_oblivious', '')}")

    # Fig 4: signal timing
    for r in incast.run_signals():
        emit("fig4_signals", r["first_ecn_us"],
             f"first_rtt_rise_us={r['first_rtt_rise_us']};"
             f"ecn_leads={r['ecn_leads']}")

    # Figs 16-20: incast
    for r in incast.run_fct(8, msg=2 * 2 ** 20) + incast.run_fct(
            32, msg=2 * 2 ** 20, topo_kw=dict(n_tor=8, hosts_per_tor=8)):
        emit(f"fig19_{r['workload']}_{r['transport']}", r["max_fct_us"],
             f"drops={r['drops']};pauses={r['pauses']};"
             f"parity={r.get('strack_over_roce', '')}")
    for r in incast.run_dynamics(16):
        emit(f"fig16_dyn_{r['transport']}", r["converge_us"],
             f"jain={r['jain_fairness']:.3f};drops={r['drops']};"
             f"pauses={r['pauses']}")
    for r in incast.run_queue_stability():
        emit(f"fig20_{r['workload']}", r["median_steady_qdelay_us"],
             f"target_us={r['target_us']};p95={r['p95_steady_qdelay_us']:.1f}")

    # Figs 21-28: collectives
    for algo in ("ring", "dbt", "hd", "a2a"):
        for ov in (1, 4):
            for r in collectives.run_collectives(algo, oversub=ov):
                emit(f"fig21_{r['workload']}_{r['transport']}",
                     r["max_collective_us"],
                     f"speedup={r.get('speedup_vs_roce', '')};"
                     f"vs_4qp={r.get('speedup_vs_roce4', '')};"
                     f"cdf_spread={r['cdf_spread']:.3f};"
                     f"done={r['finished']}/{r['total']}")

    # Roofline table (ours): summarize cached dry-run cells
    try:
        import glob
        import json
        cells = sorted(glob.glob("experiments/dryrun/*__pod.json"))
        for fn in cells:
            d = json.load(open(fn))
            r = d["roofline"]
            emit(f"roofline_{d['arch']}_{d['shape']}",
                 r["bound_time_s"] * 1e6,
                 f"dominant={r['dominant']};"
                 f"flops_ratio={r['model_flops_ratio']:.2f};"
                 f"roofline_frac={r['roofline_fraction']:.3f}")
    except Exception as e:  # noqa: BLE001
        print(f"# roofline table unavailable: {e}")


if __name__ == "__main__":
    main()
