"""Paper Figs. 16-20: incast behaviour.

* Fig 16-18: 32->1 incast dynamics — convergence time, drops (STrack, first
  RTT only) vs PFC pauses (RoCEv2), per-flow throughput fairness.
* Fig 19: FCT parity — lossy STrack must match lossless RoCEv2.
* Fig 20: queue stabilisation at the target delay across incast degrees.
"""
from __future__ import annotations

import statistics

from repro.core.params import NetworkSpec
from repro.sim.topology import full_bisection
from repro.sim.workloads import incast_scenario, run_scenario_on_sim

from .common import make_sim, run_transport, timed


def run_fct(fan_in: int = 8, msg: float = 512 * 2 ** 10, topo_kw=None,
            seed: int = 0, backend: str = "fabric"):
    """Fig 19: STrack vs RoCEv2 incast completion parity.

    Both legs run on the jitted fabric by default (STrack lossy, RoCEv2
    lossless with PFC); ``backend="events"`` uses the oracle instead.
    """
    topo_kw = topo_kw or dict(n_tor=4, hosts_per_tor=max(4, fan_in // 2))
    rows = []
    fcts = {}
    for tr in ("strack", "roce"):
        net = NetworkSpec()
        topo = full_bisection(**topo_kw)
        if backend == "fabric":
            sc = incast_scenario(topo, fan_in, msg, net=net, seed=seed)
            res, wall = timed(run_transport, tr, sc, backend="fabric")
        else:
            sim = make_sim(tr, topo, net, seed=seed)
            sc = incast_scenario(topo, fan_in, msg, net=net, seed=seed)
            res, wall = timed(run_scenario_on_sim, sim, sc, until=2e6)
        fcts[tr] = res["max_fct"]
        rows.append({"fig": "19", "workload": f"incast_{fan_in}to1",
                     "msg": msg, "transport": tr,
                     "backend": res.get("backend", "events"),
                     "max_fct_us": res["max_fct"], "drops": res["drops"],
                     "pauses": res["pauses"],
                     "unfinished": res["unfinished"], "wall_s": wall})
    rows[-1]["strack_over_roce"] = fcts["strack"] / fcts["roce"]
    return rows


def run_dynamics(fan_in: int = 16, msg: float = 2 * 2 ** 20, seed: int = 0):
    """Fig 16-18: drop timing, convergence, fairness for STrack; pauses for
    RoCEv2."""
    rows = []
    topo_kw = dict(n_tor=4, hosts_per_tor=max(4, fan_in // 2))
    for tr in ("strack", "roce"):
        net = NetworkSpec()
        topo = full_bisection(**topo_kw)
        sim = make_sim(tr, topo, net, seed=seed, log_queues=True)
        sim.rx_bytes_log = []
        sc = incast_scenario(topo, fan_in, msg, net=net, seed=seed)
        res, wall = timed(run_scenario_on_sim, sim, sc, until=4e6)
        # convergence: last time the bottleneck queue delay exceeded
        # 3x target (= still violently oscillating)
        qlog = sim.all_queue_delay_logs()
        target = net.base_rtt_us
        over = [t for t, d in qlog if d > 3 * target]
        converge = max(over) if over else 0.0
        # fairness: stddev/mean of per-flow completed bytes at half-time
        half_t = res["max_fct"] / 2
        by_flow = {}
        for t, f, b in sim.rx_bytes_log:
            if t <= half_t:
                by_flow[f] = max(by_flow.get(f, 0.0), b)
        rates = list(by_flow.values())
        jain = (sum(rates) ** 2 / (len(rates) * sum(r * r for r in rates))
                if rates and sum(rates) else 0.0)
        rows.append({"fig": "16-18", "workload": f"incast_{fan_in}to1_dyn",
                     "transport": tr, "max_fct_us": res["max_fct"],
                     "drops": res["drops"], "pauses": res["pauses"],
                     "converge_us": converge, "jain_fairness": jain,
                     "wall_s": wall})
    return rows


def run_queue_stability(degrees=(8, 16, 32), msg: float = 1 * 2 ** 20,
                        seed: int = 0):
    """Fig 20: stabilised queue delay ~= target across incast degrees."""
    rows = []
    for fan in degrees:
        net = NetworkSpec()
        topo = full_bisection(4, max(4, (fan + 3) // 4))
        sim = make_sim("strack", topo, net, seed=seed, log_queues=True,
                       qdelay_log_threshold=0.5)
        sc = incast_scenario(topo, fan, msg, net=net, seed=seed)
        res, wall = timed(run_scenario_on_sim, sim, sc, until=4e6)
        qlog = sim.all_queue_delay_logs()
        # steady state = second half of the run
        t_end = res["max_fct"]
        steady = [d for t, d in qlog if t > 0.5 * t_end]
        rows.append({
            "fig": "20", "workload": f"incast_{fan}to1_queue",
            "transport": "strack",
            "median_steady_qdelay_us": (statistics.median(steady)
                                        if steady else 0.0),
            "p95_steady_qdelay_us": (sorted(steady)[int(0.95 * len(steady))]
                                     if steady else 0.0),
            "target_us": net.base_rtt_us,
            "drops": res["drops"], "wall_s": wall})
    return rows


def run_signals(fan_in: int = 16, msg: float = 1 * 2 ** 20, seed: int = 0):
    """Fig 4: egress ECN arrives before any measurable RTT increase."""
    net = NetworkSpec()
    topo = full_bisection(4, max(4, fan_in // 2))
    sim = make_sim("strack", topo, net, seed=seed)
    sim.ack_log = []
    sc = incast_scenario(topo, fan_in, msg, net=net, seed=seed)
    res, _ = timed(run_scenario_on_sim, sim, sc, until=2e6)
    base = min(r for _, _, _, r in sim.ack_log)
    first_ecn = next((t for t, f, e, r in sim.ack_log if e), None)
    first_rtt = next((t for t, f, e, r in sim.ack_log if r > 1.5 * base),
                     None)
    return [{"fig": "4", "workload": f"incast_{fan_in}to1_signals",
             "first_ecn_us": first_ecn, "first_rtt_rise_us": first_rtt,
             "ecn_leads": (first_ecn is not None and
                           (first_rtt is None or first_ecn <= first_rtt))}]


def main():
    for r in (run_fct(8) + run_fct(32, topo_kw=dict(n_tor=8,
                                                    hosts_per_tor=8))
              + run_dynamics(16) + run_queue_stability() + run_signals()):
        print(r)


if __name__ == "__main__":
    main()
