"""Fabric perf harness — the trajectory toward the paper's 8K hosts.

Times the jitted fabric on three canonical scenarios, dense ticking vs
the event-horizon (time-warp) scan, separating compile from run
wall-clock, and writes the machine-readable ``BENCH_fabric.json``:

  * ``perm1024``  — 1024-host permutation (scale: per-tick cost at 32x32)
  * ``ring8``     — 8-rank chunked ring allreduce (dependency-chained
                    trace: SACK-pipe round trips + dep stalls dominate)
  * ``incast256`` — 256-to-1 incast (drop/RTO recovery gaps + long
                    post-completion tail)

Each scenario runs both modes through the same compiled-program cache and
asserts dense/warp parity (identical FCTs, drops, pauses) before
reporting, so a speedup number can never come from a semantics drift.

    PYTHONPATH=src python -m benchmarks.perf [--out BENCH_fabric.json]
    PYTHONPATH=src python -m benchmarks.perf --smoke   # CI floor check
    PYTHONPATH=src python -m benchmarks.perf --check BENCH_fabric.json

``make bench`` fails loudly (non-zero exit) when any scenario's
``parity_ok`` is false or the written JSON does not match the schema
(``validate_report``); ``--check`` re-validates an existing report.

``--smoke`` runs only the 2k-tick 16-host canary and fails if the warm
time-warped fabric drops below a ticks/sec floor — the fast CI guard
``make smoke`` chains (full runs: ``make bench``).  Schema and scaling
notes: docs/performance.md.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time

import jax

from repro.core.params import NetworkSpec
from repro.sim.topology import full_bisection
from repro.sim.workloads import (RunConfig, Scenario, collective_scenario,
                                 incast_scenario, permutation_scenario, run)

#: Conservative CI floor for the warm time-warped 2k-tick canary.  The
#: reference container does ~50k warp ticks/s on this shape; flag only
#: order-of-magnitude regressions, not machine noise.
SMOKE_FLOOR_TICKS_PER_S = 5_000.0


def canonical_scenarios() -> dict:
    """name -> (Scenario, RunConfig overrides dict).  Kept in one place so
    docs, bench and tests agree on what the canaries are."""
    return {
        "perm1024": (
            permutation_scenario(full_bisection(32, 32), 64 * 2 ** 10,
                                 net=NetworkSpec(link_gbps=400.0), seed=0),
            {}),
        "ring8": (
            collective_scenario(full_bisection(2, 4), "ring", 1, 8,
                                512 * 2 ** 10,
                                net=NetworkSpec(link_gbps=100.0), seed=0,
                                chunk=32 * 2 ** 10),
            {}),
        # RoCEv2 (lossless, DCQCN): the motivation's incast case — rate
        # recovery backoff and pause phases leave long pacing gaps the
        # event-horizon scan collapses.  (An STrack incast is the warp
        # worst case instead: Algo 3/4 *targets* a standing queue, so the
        # fabric is busy wall-to-wall until completion.)
        "incast256": (
            incast_scenario(full_bisection(16, 17), 256, 64 * 2 ** 10,
                            net=NetworkSpec(link_gbps=100.0), seed=0),
            {"protocol": "rocev2"}),
    }


def _time_mode(sc: Scenario, n_ticks: int, warp: bool, repeats: int,
               **cfg_kw) -> tuple[dict, dict]:
    cfg = RunConfig(backend="fabric", time_warp=warp, trace_every=0,
                    n_ticks=n_ticks, **cfg_kw)
    t0 = time.perf_counter()
    res = run(sc, cfg)
    cold_s = time.perf_counter() - t0
    run_s = cold_s
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = run(sc, cfg)
        run_s = min(run_s, time.perf_counter() - t0)
    row = {
        "cold_s": round(cold_s, 4),
        "run_s": round(run_s, 4),
        "compile_s": round(max(0.0, cold_s - run_s), 4),
        "ticks_per_s": round(n_ticks / run_s, 1),
    }
    if warp:
        row["warp_trips"] = res.get("warp_trips")
    return row, res


def _parity(dense: dict, warp: dict) -> bool:
    keys = ["max_fct", "avg_fct", "unfinished", "drops", "pauses"]
    keys += [k for k in ("max_collective_time", "finished_groups")
             if k in dense]
    return all(dense[k] == warp[k] or
               (dense[k] != dense[k] and warp[k] != warp[k])  # both NaN
               for k in keys)


def bench_scenario(name: str, sc: Scenario, cfg_kw: dict,
                   repeats: int = 2) -> dict:
    n_ticks = sc.default_ticks()
    dense_row, dense_res = _time_mode(sc, n_ticks, False, repeats, **cfg_kw)
    warp_row, warp_res = _time_mode(sc, n_ticks, True, repeats, **cfg_kw)
    row = {
        "n_ticks": n_ticks,
        "n_hosts": sc.topo.n_hosts,
        "n_msgs": len(sc.messages),
        "dense": dense_row,
        "warp": warp_row,
        "speedup": round(dense_row["run_s"] / warp_row["run_s"], 2),
        "parity_ok": _parity(dense_res, warp_res),
        "unfinished": dense_res["unfinished"],
        "max_fct_us": dense_res["max_fct"],
    }
    print(f"bench[{name}]: {n_ticks} ticks x {row['n_msgs']} msgs on "
          f"{row['n_hosts']} hosts | dense {dense_row['run_s']:.3f}s "
          f"({dense_row['ticks_per_s']:,.0f} t/s) | warp "
          f"{warp_row['run_s']:.3f}s ({warp_row['warp_trips']} trips) | "
          f"{row['speedup']}x, parity={'ok' if row['parity_ok'] else 'FAIL'}")
    return row


#: BENCH_fabric.json schema: required keys and their types, per level.
#: ``validate_report`` walks this so a malformed report (hand-edited,
#: truncated write, schema drift) fails the gate as loudly as a parity
#: failure does.
_SCHEMA_META = {"utc": str, "jax": str, "backend": str, "platform": str}
_SCHEMA_SCENARIO = {"n_ticks": int, "n_hosts": int, "n_msgs": int,
                    "dense": dict, "warp": dict, "speedup": (int, float),
                    "parity_ok": bool, "unfinished": int,
                    "max_fct_us": (int, float)}
_SCHEMA_MODE = {"cold_s": (int, float), "run_s": (int, float),
                "compile_s": (int, float), "ticks_per_s": (int, float)}


def validate_report(report: dict) -> list:
    """Schema-check one BENCH_fabric.json report dict.

    Returns a list of human-readable problems (empty = valid): missing or
    mis-typed keys at the meta / scenario / mode levels, and any scenario
    whose ``parity_ok`` gate is false — the caller turns a non-empty list
    into a non-zero exit.
    """
    problems = []

    def chk(d, schema, where):
        if not isinstance(d, dict):
            problems.append(f"{where}: expected an object, got "
                            f"{type(d).__name__}")
            return False
        for k, t in schema.items():
            if k not in d:
                problems.append(f"{where}: missing key {k!r}")
            elif not isinstance(d[k], t):
                problems.append(f"{where}.{k}: expected "
                                f"{getattr(t, '__name__', t)}, got "
                                f"{type(d[k]).__name__}")
        return True

    if not isinstance(report, dict):
        return [f"report: expected an object, got {type(report).__name__}"]
    chk(report.get("meta"), _SCHEMA_META, "meta")
    scenarios = report.get("scenarios")
    if not isinstance(scenarios, dict) or not scenarios:
        problems.append("scenarios: missing or empty")
        return problems
    for name, row in scenarios.items():
        if not chk(row, _SCHEMA_SCENARIO, f"scenarios.{name}"):
            continue
        for mode in ("dense", "warp"):
            if isinstance(row.get(mode), dict):
                chk(row[mode], _SCHEMA_MODE, f"scenarios.{name}.{mode}")
        if row.get("parity_ok") is False:
            problems.append(
                f"scenarios.{name}: parity_ok is FALSE — the time-warped "
                f"scan diverged from dense ticking; a speedup number from "
                f"this report cannot be trusted")
    return problems


def check_report_file(path: str) -> int:
    """Validate an existing BENCH_fabric.json; returns a process exit
    code (0 ok, 1 schema/parity problems, 2 unreadable)."""
    try:
        with open(path) as f:
            report = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench gate: cannot read {path}: {e}", file=sys.stderr)
        return 2
    problems = validate_report(report)
    for p in problems:
        print(f"bench gate: {path}: {p}", file=sys.stderr)
    if problems:
        return 1
    print(f"bench gate ok: {path} "
          f"({len(report['scenarios'])} scenarios, parity ok)")
    return 0


def bench_all(out_path: str = "BENCH_fabric.json",
              repeats: int = 2) -> dict:
    report = {
        "meta": {
            "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
        },
        "scenarios": {},
    }
    for name, (sc, cfg_kw) in canonical_scenarios().items():
        report["scenarios"][name] = bench_scenario(name, sc, cfg_kw,
                                                   repeats=repeats)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out_path}")
    # Loud gate: schema-check the report we just wrote and fail the
    # process (non-zero exit) if any scenario's dense/warp parity broke —
    # a silent parity drift would invalidate every speedup number.
    problems = validate_report(report)
    if problems:
        for p in problems:
            print(f"bench gate: {p}", file=sys.stderr)
        sys.exit(1)
    return report


def smoke(n_ticks: int = 2000,
          floor: float = SMOKE_FLOOR_TICKS_PER_S) -> None:
    """2k-tick perf canary: the warm time-warped fabric must beat
    ``floor`` ticks/sec and agree exactly with dense ticking."""
    sc = permutation_scenario(full_bisection(4, 4), 64 * 2 ** 10,
                              net=NetworkSpec(), seed=0)
    dense_row, dense_res = _time_mode(sc, n_ticks, False, repeats=1)
    warp_row, warp_res = _time_mode(sc, n_ticks, True, repeats=1)
    tps = warp_row["ticks_per_s"]
    assert _parity(dense_res, warp_res), (dense_res, warp_res)
    assert tps >= floor, (
        f"perf-smoke FAILED: warm time-warp fabric ran {tps:,.0f} ticks/s "
        f"< floor {floor:,.0f} on the {n_ticks}-tick canary")
    print(f"perf-smoke ok: warp {tps:,.0f} ticks/s (floor {floor:,.0f}), "
          f"dense {dense_row['ticks_per_s']:,.0f} t/s, "
          f"{warp_row['warp_trips']} trips, parity exact")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_fabric.json")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--smoke", action="store_true",
                    help="2k-tick ticks/sec floor canary (CI)")
    ap.add_argument("--floor", type=float, default=SMOKE_FLOOR_TICKS_PER_S)
    ap.add_argument("--check", metavar="PATH",
                    help="validate an existing BENCH_fabric.json (schema "
                         "+ parity gate) without running anything")
    args = ap.parse_args()
    if args.check:
        sys.exit(check_report_file(args.check))
    if args.smoke:
        smoke(floor=args.floor)
        return
    bench_all(args.out, repeats=args.repeats)


if __name__ == "__main__":
    main()
