"""Fabric perf harness — the trajectory toward the paper's 8K hosts.

Times the jitted fabric on the canonical scenarios, dense ticking vs the
event-horizon (time-warp) scan, separating compile from run wall-clock,
and writes the machine-readable ``BENCH_fabric.json``:

  * ``perm1024``    — 1024-host permutation (scale: per-tick cost at 32x32)
  * ``ring8``       — 8-rank chunked ring allreduce (dependency-chained
                      trace: SACK-pipe round trips + dep stalls dominate)
  * ``incast256``   — 256-to-1 incast (drop/RTO recovery gaps + long
                      post-completion tail)
  * ``perm8k``      — the paper's cluster scale: 8192-host permutation,
                      warp-only (dense ticking at 8K is not a useful
                      number), parity from a small-scale oracle spot-check
  * ``allreduce8k`` — 8192 ranks of halving-doubling allreduce as 64
                      concurrent 128-rank jobs on one shared 8K fabric
                      (multi-tenant contention included), run under the
                      active-set formulation

plus a **scale axis** (``n_hosts`` vs warp ticks/sec, compile seconds and
``program_builds``) over 64 / 256 / 1024 / 8192-host permutations, so the
XLA compile-time ceiling is tracked across PRs instead of rediscovered,
and a **kernel-backend axis**: every scenario's warp run is repeated per
``FabricConfig.kernel_backend`` (``jnp`` inline stages vs the Pallas
hot-path kernels; ``pallas_interpret`` on CPU hosts, compiled ``pallas``
on TPU/GPU) under a bit-exact parity gate, and the scale axis carries a
``kernel_backend`` tag per point — so BENCH_fabric.json tracks the
kernel trajectory across PRs.  Select backends explicitly with
``--kernel-backends jnp,pallas_interpret``.

Dense+warp scenarios assert dense/warp parity (identical FCTs, drops,
pauses) before reporting; warp-only scenarios run the same workload
generator at small scale against the events oracle and gate on the fuzz
parity band.  Either way a speedup number can never come from a
semantics drift.

    PYTHONPATH=src python -m benchmarks.perf [--out BENCH_fabric.json]
    PYTHONPATH=src python -m benchmarks.perf --smoke   # CI floor check
    PYTHONPATH=src python -m benchmarks.perf --scale   # 512-host floor
    PYTHONPATH=src python -m benchmarks.perf --check BENCH_fabric.json
    PYTHONPATH=src python -m benchmarks.perf --profile traces/fabric

``make bench`` fails loudly (non-zero exit) when any scenario's
``parity_ok`` is false, when the written JSON does not match the schema
(``validate_report``), or when any scenario's warp ticks/sec regressed
more than ``REGRESSION_TOL`` against the previously committed
BENCH_fabric.json; ``--check`` re-validates an existing report.

``--smoke`` runs only the 2k-tick 16-host canary and fails if the warm
time-warped fabric drops below a ticks/sec floor; ``--scale`` is the
larger 512-host warp smoke point ``make bench`` chains.  Schema and
scaling notes: docs/performance.md.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time

import jax

from repro.core.params import NetworkSpec
from repro.sim import fabric
from repro.sim.topology import full_bisection
from repro.sim.workloads import (RunConfig, Scenario, collective_scenario,
                                 incast_scenario, permutation_scenario, run)

#: Conservative CI floor for the warm time-warped 2k-tick canary.  The
#: reference container does ~50k warp ticks/s on this shape; flag only
#: order-of-magnitude regressions, not machine noise.
SMOKE_FLOOR_TICKS_PER_S = 5_000.0

#: Floor for the 512-host ``--scale`` smoke point (warm warp run).  A
#: single-core container does a few thousand ticks/s here; like the 2k
#: canary this flags order-of-magnitude breakage only.
SCALE_FLOOR_TICKS_PER_S = 500.0

#: ``make bench`` regression gate: fail when any scenario's warm warp
#: ticks/sec drops more than this fraction below the committed report.
REGRESSION_TOL = 0.20

#: Fabric-vs-oracle band for the warp-only scenarios' small-scale parity
#: spot-check — the differential-fuzz band (tests/test_fuzz_parity.py).
SPOT_BAND = (0.7, 1.4)

#: Lane cap for the 8K-rank allreduce: halving-doubling releases ~1-2
#: messages per rank at a time (8192 ranks), so 32k lanes is ~2x headroom
#: over the peak live-flow count while cutting per-tick transport work
#: ~3.5x vs the 114,688-flow dense formulation.  The program raises if
#: the cap is ever exceeded, so a too-small cap fails loudly mid-bench.
ALLREDUCE8K_ACTIVE_CAP = 32_768

#: Summary keys the kernel-backend parity gate compares BIT-exactly (the
#: Pallas kernels run the same stage cores as the jnp path, so any
#: difference at all is a bug, not noise).
_KERNEL_PARITY_KEYS = ("max_fct", "avg_fct", "drops", "pauses",
                       "unfinished", "max_collective_time",
                       "finished_groups")


def default_kernel_backends() -> list:
    """Kernel backends the bench sweeps by default: the inline jnp path
    plus interpret-mode Pallas on CPU hosts (same XLA ops underneath, so
    it is cheap and bit-exact-checkable anywhere) or compiled Pallas on
    TPU/GPU."""
    if jax.default_backend() == "cpu":
        return ["jnp", "pallas_interpret"]
    return ["jnp", "pallas"]


def canonical_scenarios() -> dict:
    """name -> (Scenario, RunConfig overrides dict).  Kept in one place so
    docs, bench and tests agree on what the canaries are."""
    return {
        "perm1024": (
            permutation_scenario(full_bisection(32, 32), 64 * 2 ** 10,
                                 net=NetworkSpec(link_gbps=400.0), seed=0),
            {}),
        "ring8": (
            collective_scenario(full_bisection(2, 4), "ring", 1, 8,
                                512 * 2 ** 10,
                                net=NetworkSpec(link_gbps=100.0), seed=0,
                                chunk=32 * 2 ** 10),
            {}),
        # RoCEv2 (lossless, DCQCN): the motivation's incast case — rate
        # recovery backoff and pause phases leave long pacing gaps the
        # event-horizon scan collapses.  (An STrack incast is the warp
        # worst case instead: Algo 3/4 *targets* a standing queue, so the
        # fabric is busy wall-to-wall until completion.)
        "incast256": (
            incast_scenario(full_bisection(16, 17), 256, 64 * 2 ** 10,
                            net=NetworkSpec(link_gbps=100.0), seed=0),
            {"protocol": "rocev2"}),
    }


def scale_scenarios() -> dict:
    """The paper's 8K-host scenarios: warp-only (spec below) with a
    small-scale oracle spot-check standing in for the dense-parity gate.
    name -> (Scenario, cfg overrides, spot Scenario, spot cfg overrides).
    """
    net400 = NetworkSpec(link_gbps=400.0)
    net100 = NetworkSpec(link_gbps=100.0)
    return {
        "perm8k": (
            permutation_scenario(full_bisection(128, 64), 64 * 2 ** 10,
                                 net=net400, seed=0),
            {},
            permutation_scenario(full_bisection(4, 4), 64 * 2 ** 10,
                                 net=net400, seed=0),
            {}),
        "allreduce8k": (
            collective_scenario(full_bisection(128, 64), "hd", 64, 128,
                                128 * 2 ** 10, net=net100, seed=0),
            {"active_cap": ALLREDUCE8K_ACTIVE_CAP},
            collective_scenario(full_bisection(4, 4), "hd", 2, 8,
                                128 * 2 ** 10, net=net100, seed=0),
            {"active_cap": 48}),
    }


#: n_hosts -> full_bisection dims for the compile/throughput scale axis.
#: The 8192 point reuses the perm8k scenario run (same generator/params).
SCALE_AXIS_DIMS = {64: (8, 8), 256: (16, 16), 1024: (32, 32)}


def _time_mode(sc: Scenario, n_ticks: int, warp: bool, repeats: int,
               **cfg_kw) -> tuple[dict, dict]:
    cfg = RunConfig(backend="fabric", time_warp=warp, trace_every=0,
                    n_ticks=n_ticks, **cfg_kw)
    b0 = fabric.program_builds
    t0 = time.perf_counter()
    res = run(sc, cfg)
    cold_s = time.perf_counter() - t0
    run_s = cold_s
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = run(sc, cfg)
        run_s = min(run_s, time.perf_counter() - t0)
    row = {
        "cold_s": round(cold_s, 4),
        "run_s": round(run_s, 4),
        "compile_s": round(max(0.0, cold_s - run_s), 4),
        "ticks_per_s": round(n_ticks / run_s, 1),
        "program_builds": fabric.program_builds - b0,
    }
    if warp:
        row["warp_trips"] = res.get("warp_trips")
    return row, res


def _parity(dense: dict, warp: dict) -> bool:
    keys = ["max_fct", "avg_fct", "unfinished", "drops", "pauses"]
    keys += [k for k in ("max_collective_time", "finished_groups")
             if k in dense]
    return all(dense[k] == warp[k] or
               (dense[k] != dense[k] and warp[k] != warp[k])  # both NaN
               for k in keys)


def _kernel_parity_exact(a: dict, b: dict) -> bool:
    return all(a.get(k) == b.get(k) or
               (a.get(k) != a.get(k) and b.get(k) != b.get(k))  # both NaN
               for k in _KERNEL_PARITY_KEYS)


def _bench_kernel_rows(name: str, sc: Scenario, n_ticks: int,
                       repeats: int, cfg_kw: dict, base_res: dict,
                       kernel_backends: list) -> dict:
    """Warp re-runs of one scenario per non-jnp kernel backend, each
    gated BIT-exact against the jnp warp summary (same stage cores, so
    exactness — not a band — is the contract)."""
    rows = {}
    for kb in kernel_backends:
        if kb == "jnp":
            continue
        krow, kres = _time_mode(sc, n_ticks, True, repeats,
                                kernel_backend=kb, **cfg_kw)
        krow["parity_exact"] = _kernel_parity_exact(base_res, kres)
        rows[kb] = krow
        print(f"bench[{name}] kernels[{kb}]: warp {krow['run_s']:.3f}s "
              f"({krow['ticks_per_s']:,.0f} t/s), parity="
              f"{'exact' if krow['parity_exact'] else 'FAIL'}")
    return rows


def bench_scenario(name: str, sc: Scenario, cfg_kw: dict,
                   repeats: int = 2, kernel_backends: list = ()) -> dict:
    n_ticks = sc.default_ticks()
    b0 = fabric.program_builds
    dense_row, dense_res = _time_mode(sc, n_ticks, False, repeats, **cfg_kw)
    warp_row, warp_res = _time_mode(sc, n_ticks, True, repeats, **cfg_kw)
    row = {
        "n_ticks": n_ticks,
        "n_hosts": sc.topo.n_hosts,
        "n_msgs": len(sc.messages),
        "dense": dense_row,
        "warp": warp_row,
        "speedup": round(dense_row["run_s"] / warp_row["run_s"], 2),
        "parity_ok": _parity(dense_res, warp_res),
        "unfinished": dense_res["unfinished"],
        "max_fct_us": dense_res["max_fct"],
        "program_builds_total": fabric.program_builds - b0,
    }
    print(f"bench[{name}]: {n_ticks} ticks x {row['n_msgs']} msgs on "
          f"{row['n_hosts']} hosts | dense {dense_row['run_s']:.3f}s "
          f"({dense_row['ticks_per_s']:,.0f} t/s) | warp "
          f"{warp_row['run_s']:.3f}s ({warp_row['warp_trips']} trips) | "
          f"{row['speedup']}x, parity={'ok' if row['parity_ok'] else 'FAIL'}")
    kernels = _bench_kernel_rows(name, sc, n_ticks, repeats, cfg_kw,
                                 warp_res, kernel_backends)
    if kernels:
        row["kernels"] = kernels
    return row


def _oracle_spotcheck(sc: Scenario, cfg_kw: dict) -> dict:
    """Small-scale fabric-vs-events run of a warp-only scenario's
    generator; ok iff the completion-time ratio sits in the fuzz band."""
    fb = run(sc, RunConfig(backend="fabric", time_warp=True,
                           trace_every=0, **cfg_kw))
    ev_kw = {k: v for k, v in cfg_kw.items()
             if k not in ("active_cap", "shard")}
    ev = run(sc, RunConfig(backend="events", until=2e7, **ev_kw))
    if "max_collective_time" in fb:
        a, b = fb["max_collective_time"], ev["max_collective_time"]
    else:
        a, b = fb["max_fct"], ev["max_fct"]
    ratio = a / b
    ok = (SPOT_BAND[0] < ratio < SPOT_BAND[1]
          and fb["unfinished"] == 0 and ev["unfinished"] == 0)
    return {"n_hosts": sc.topo.n_hosts, "n_msgs": len(sc.messages),
            "fabric_us": round(a, 3), "events_us": round(b, 3),
            "ratio": round(ratio, 4), "ok": ok}


def bench_scenario_warp_only(name: str, sc: Scenario, cfg_kw: dict,
                             spot_sc: Scenario, spot_kw: dict,
                             repeats: int = 1,
                             kernel_backends: list = ()) -> dict:
    """8K-scale scenario: warp scan only (a dense 8K run is pure heat),
    with the oracle spot-check providing the parity gate."""
    spot = _oracle_spotcheck(spot_sc, spot_kw)
    n_ticks = sc.default_ticks()
    b0 = fabric.program_builds
    warp_row, warp_res = _time_mode(sc, n_ticks, True, repeats, **cfg_kw)
    row = {
        "n_ticks": n_ticks,
        "n_hosts": sc.topo.n_hosts,
        "n_msgs": len(sc.messages),
        "warp": warp_row,
        "warp_only": True,
        "parity_ok": bool(spot["ok"] and warp_res["unfinished"] == 0),
        "parity_spotcheck": spot,
        "unfinished": warp_res["unfinished"],
        "max_fct_us": warp_res["max_fct"],
        "program_builds_total": fabric.program_builds - b0,
    }
    if "active_cap" in cfg_kw:
        row["active_cap"] = cfg_kw["active_cap"]
    print(f"bench[{name}]: {n_ticks} ticks x {row['n_msgs']} msgs on "
          f"{row['n_hosts']} hosts | warp {warp_row['run_s']:.3f}s "
          f"({warp_row['ticks_per_s']:,.0f} t/s, {warp_row['warp_trips']} "
          f"trips, compile {warp_row['compile_s']:.1f}s) | spot-check "
          f"ratio {spot['ratio']} on {spot['n_hosts']} hosts, "
          f"parity={'ok' if row['parity_ok'] else 'FAIL'}")
    kernels = _bench_kernel_rows(name, sc, n_ticks, repeats, cfg_kw,
                                 warp_res, kernel_backends)
    if kernels:
        row["kernels"] = kernels
    return row


def bench_scale_axis(repeats: int = 1, kernel_backends: list = ()) -> list:
    """Warp permutation runs across host counts x kernel backends with a
    cleared program cache per point, so ``compile_s`` and
    ``program_builds`` measure the real per-scale build cost (the
    compile-time ceiling ROADMAP names) per execution substrate."""
    axis = []
    backends = list(kernel_backends) or ["jnp"]
    for n_hosts, (t, h) in sorted(SCALE_AXIS_DIMS.items()):
        sc = permutation_scenario(full_bisection(t, h), 64 * 2 ** 10,
                                  net=NetworkSpec(link_gbps=400.0), seed=0)
        n_ticks = sc.default_ticks()
        for kb in backends:
            fabric.clear_program_cache()
            row, _ = _time_mode(sc, n_ticks, True, repeats,
                                kernel_backend=kb)
            axis.append({"n_hosts": n_hosts, "n_ticks": n_ticks,
                         "kernel_backend": kb,
                         "ticks_per_s": row["ticks_per_s"],
                         "compile_s": row["compile_s"],
                         "program_builds": row["program_builds"],
                         "warp_trips": row["warp_trips"]})
            print(f"scale[{n_hosts:>5} hosts, {kb}]: "
                  f"{row['ticks_per_s']:>9,.1f} t/s warm, compile "
                  f"{row['compile_s']:.2f}s, {row['program_builds']} builds")
    return axis


#: BENCH_fabric.json schema: required keys and their types, per level.
#: ``validate_report`` walks this so a malformed report (hand-edited,
#: truncated write, schema drift) fails the gate as loudly as a parity
#: failure does.
_SCHEMA_META = {"utc": str, "jax": str, "backend": str, "platform": str}
#: ``program_builds_total`` (scenario level) is the whole-scenario build
#: count across all modes — a diagnostic.  The retrace-regression hook
#: reads the per-mode ``program_builds`` inside ``warp``/``dense``
#: (``_SCHEMA_MODE``); the throughput regression gate reads
#: ``warp.ticks_per_s``.  Earlier reports spelled the scenario-level
#: field ``program_builds`` too, shadowing the per-mode one — the rename
#: keeps the two hooks unambiguous.
_SCHEMA_SCENARIO = {"n_ticks": int, "n_hosts": int, "n_msgs": int,
                    "warp": dict, "parity_ok": bool, "unfinished": int,
                    "max_fct_us": (int, float), "program_builds_total": int}
#: dense+speedup are required unless the row is flagged ``warp_only``.
_SCHEMA_SCENARIO_DENSE = {"dense": dict, "speedup": (int, float)}
_SCHEMA_MODE = {"cold_s": (int, float), "run_s": (int, float),
                "compile_s": (int, float), "ticks_per_s": (int, float),
                "program_builds": int}
#: per-backend warp re-run under ``scenarios.<name>.kernels.<backend>``;
#: ``parity_exact`` is the bit-exactness gate vs the jnp warp summary.
_SCHEMA_KERNEL_ROW = dict(_SCHEMA_MODE, parity_exact=bool)
_SCHEMA_SCALE_POINT = {"n_hosts": int, "n_ticks": int,
                       "kernel_backend": str,
                       "ticks_per_s": (int, float),
                       "compile_s": (int, float), "program_builds": int}


def validate_report(report: dict) -> list:
    """Schema-check one BENCH_fabric.json report dict.

    Returns a list of human-readable problems (empty = valid): missing or
    mis-typed keys at the meta / scenario / mode / kernels / scale-axis
    levels, any scenario whose ``parity_ok`` gate is false, and any
    kernel-backend row whose ``parity_exact`` gate is false — the caller
    turns a non-empty list into a non-zero exit.

    Which field feeds which gate (the point of the
    ``program_builds_total`` rename):

      * the **throughput regression gate** (``regression_problems``)
        reads ``scenarios.<name>.warp.ticks_per_s`` — nothing else;
      * the **retrace-regression hook** reads the per-mode
        ``program_builds`` inside ``warp`` / ``dense`` /
        ``kernels.<backend>`` rows (a warm re-run that rebuilds its
        program is a cache bug);
      * scenario-level ``program_builds_total`` is the whole-scenario
        build count across every mode — a diagnostic, read by no gate.
    """
    problems = []

    def chk(d, schema, where):
        if not isinstance(d, dict):
            problems.append(f"{where}: expected an object, got "
                            f"{type(d).__name__}")
            return False
        for k, t in schema.items():
            if k not in d:
                problems.append(f"{where}: missing key {k!r}")
            elif not isinstance(d[k], t):
                problems.append(f"{where}.{k}: expected "
                                f"{getattr(t, '__name__', t)}, got "
                                f"{type(d[k]).__name__}")
        return True

    if not isinstance(report, dict):
        return [f"report: expected an object, got {type(report).__name__}"]
    chk(report.get("meta"), _SCHEMA_META, "meta")
    scenarios = report.get("scenarios")
    if not isinstance(scenarios, dict) or not scenarios:
        problems.append("scenarios: missing or empty")
        return problems
    for name, row in scenarios.items():
        if not chk(row, _SCHEMA_SCENARIO, f"scenarios.{name}"):
            continue
        modes = ["warp"]
        if not row.get("warp_only"):
            chk(row, _SCHEMA_SCENARIO_DENSE, f"scenarios.{name}")
            modes.append("dense")
        for mode in modes:
            if isinstance(row.get(mode), dict):
                chk(row[mode], _SCHEMA_MODE, f"scenarios.{name}.{mode}")
        # kernels axis is optional (jnp-only sweeps), but when present
        # every backend row must be well-formed and bit-exact
        if "kernels" in row:
            if not isinstance(row["kernels"], dict) or not row["kernels"]:
                problems.append(f"scenarios.{name}.kernels: expected a "
                                f"non-empty object")
            else:
                for kb, krow in row["kernels"].items():
                    where = f"scenarios.{name}.kernels.{kb}"
                    if not chk(krow, _SCHEMA_KERNEL_ROW, where):
                        continue
                    if krow.get("parity_exact") is False:
                        problems.append(
                            f"{where}: parity_exact is FALSE — the "
                            f"{kb} kernel backend diverged from the "
                            f"inline jnp stages; the kernels must be "
                            f"bit-exact, so this is a kernel bug, not "
                            f"noise")
        if row.get("parity_ok") is False:
            problems.append(
                f"scenarios.{name}: parity_ok is FALSE — the fabric "
                f"diverged from its reference (dense ticking or the "
                f"events-oracle spot-check); a speedup number from this "
                f"report cannot be trusted")
    # scale axis is optional for backward compatibility with pre-scale
    # reports, but when present every point must be well-formed
    if "scale_axis" in report:
        axis = report["scale_axis"]
        if not isinstance(axis, list) or not axis:
            problems.append("scale_axis: expected a non-empty list")
        else:
            for i, pt in enumerate(axis):
                chk(pt, _SCHEMA_SCALE_POINT, f"scale_axis[{i}]")
    return problems


def regression_problems(new: dict, baseline: dict,
                        tol: float = REGRESSION_TOL) -> list:
    """Compare warm warp ticks/sec per scenario against the committed
    report; >tol fractional drops are gate failures.  The gate reads
    exactly ``scenarios.<name>.warp.ticks_per_s`` on both sides — never
    the kernels sub-rows, the dense row, or any ``program_builds*``
    field.  Scenarios missing on either side are skipped (new scenarios
    land without a baseline)."""
    problems = []
    old_sc = (baseline or {}).get("scenarios") or {}
    new_sc = (new or {}).get("scenarios") or {}
    for name in sorted(set(old_sc) & set(new_sc)):
        try:
            old_tps = float(old_sc[name]["warp"]["ticks_per_s"])
            new_tps = float(new_sc[name]["warp"]["ticks_per_s"])
        except (KeyError, TypeError, ValueError):
            continue
        if old_tps > 0 and new_tps < (1.0 - tol) * old_tps:
            problems.append(
                f"scenarios.{name}: warp ticks/sec regressed "
                f"{(1 - new_tps / old_tps) * 100:.1f}% "
                f"({old_tps:,.1f} -> {new_tps:,.1f}; gate is {tol:.0%})")
    return problems


def check_report_file(path: str) -> int:
    """Validate an existing BENCH_fabric.json; returns a process exit
    code (0 ok, 1 schema/parity problems, 2 unreadable)."""
    try:
        with open(path) as f:
            report = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench gate: cannot read {path}: {e}", file=sys.stderr)
        return 2
    problems = validate_report(report)
    for p in problems:
        print(f"bench gate: {path}: {p}", file=sys.stderr)
    if problems:
        return 1
    print(f"bench gate ok: {path} "
          f"({len(report['scenarios'])} scenarios, parity ok)")
    return 0


def _load_baseline(path: str):
    """Read the previously committed report for the regression gate.

    A missing, unreadable, corrupt, or non-object baseline means "no
    baseline" — logged loudly, never a traceback: a fresh clone or a
    mangled committed report must not block regenerating the report."""
    try:
        with open(path) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"bench gate: no baseline at {path} — regression gate "
              f"skipped for this run", file=sys.stderr)
        return None
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench gate: baseline {path} unreadable ({e}) — treating "
              f"as no baseline; regression gate skipped", file=sys.stderr)
        return None
    if not isinstance(baseline, dict):
        print(f"bench gate: baseline {path} is not a JSON object "
              f"({type(baseline).__name__}) — treating as no baseline",
              file=sys.stderr)
        return None
    return baseline


def bench_all(out_path: str = "BENCH_fabric.json",
              repeats: int = 2, kernel_backends: list = None,
              history_path: str = "BENCH_history.jsonl") -> dict:
    if kernel_backends is None:
        kernel_backends = default_kernel_backends()
    # the committed report (if any) is the regression baseline — read it
    # BEFORE overwriting
    baseline = _load_baseline(out_path)
    report = {
        "meta": {
            "utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "platform": platform.platform(),
        },
        "scenarios": {},
    }
    # scale axis first: each point measures a cold build (cache cleared
    # per host-count x backend point)
    report["scale_axis"] = bench_scale_axis(repeats=max(1, repeats - 1),
                                            kernel_backends=kernel_backends)
    for name, (sc, cfg_kw) in canonical_scenarios().items():
        report["scenarios"][name] = bench_scenario(
            name, sc, cfg_kw, repeats=repeats,
            kernel_backends=kernel_backends)
    for name, (sc, cfg_kw, spot_sc, spot_kw) in scale_scenarios().items():
        row = bench_scenario_warp_only(name, sc, cfg_kw, spot_sc, spot_kw,
                                       repeats=1,
                                       kernel_backends=kernel_backends)
        report["scenarios"][name] = row
        if name == "perm8k":
            # the 8192-host scale points reuse the perm8k runs (jnp warp
            # row + the per-backend kernels rows) instead of re-timing
            kern = row.get("kernels", {})
            for kb, w in [("jnp", row["warp"])] + sorted(kern.items()):
                if kb != "jnp" and kb not in kernel_backends:
                    continue
                report["scale_axis"].append({
                    "n_hosts": row["n_hosts"], "n_ticks": row["n_ticks"],
                    "kernel_backend": kb,
                    "ticks_per_s": w["ticks_per_s"],
                    "compile_s": w["compile_s"],
                    "program_builds": w["program_builds"],
                    "warp_trips": w["warp_trips"]})
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {out_path}")
    # Loud gates: (1) schema + parity on the report we just wrote,
    # (2) warp throughput vs the previously committed report, and (3) the
    # cross-PR trend gate over BENCH_history.jsonl — fail the process on
    # any of them, never bury a regression in a report nobody reads.
    problems = validate_report(report)
    problems += regression_problems(report, baseline)
    from repro.obs import trend
    problems += trend.gate_and_append(history_path, report,
                                      tol=REGRESSION_TOL)
    if problems:
        for p in problems:
            print(f"bench gate: {p}", file=sys.stderr)
        sys.exit(1)
    return report


def smoke(n_ticks: int = 2000,
          floor: float = SMOKE_FLOOR_TICKS_PER_S) -> None:
    """2k-tick perf canary: the warm time-warped fabric must beat
    ``floor`` ticks/sec and agree exactly with dense ticking."""
    sc = permutation_scenario(full_bisection(4, 4), 64 * 2 ** 10,
                              net=NetworkSpec(), seed=0)
    dense_row, dense_res = _time_mode(sc, n_ticks, False, repeats=1)
    warp_row, warp_res = _time_mode(sc, n_ticks, True, repeats=1)
    tps = warp_row["ticks_per_s"]
    assert _parity(dense_res, warp_res), (dense_res, warp_res)
    assert tps >= floor, (
        f"perf-smoke FAILED: warm time-warp fabric ran {tps:,.0f} ticks/s "
        f"< floor {floor:,.0f} on the {n_ticks}-tick canary")
    print(f"perf-smoke ok: warp {tps:,.0f} ticks/s (floor {floor:,.0f}), "
          f"dense {dense_row['ticks_per_s']:,.0f} t/s, "
          f"{warp_row['warp_trips']} trips, parity exact")


def scale_smoke(floor: float = SCALE_FLOOR_TICKS_PER_S) -> None:
    """512-host warp smoke point (``make bench`` chains this): a midsize
    permutation must beat a conservative warm ticks/sec floor, catching
    at-scale scan regressions the 16-host canary can't see."""
    sc = permutation_scenario(full_bisection(16, 32), 64 * 2 ** 10,
                              net=NetworkSpec(link_gbps=400.0), seed=0)
    n_ticks = sc.default_ticks()
    warp_row, warp_res = _time_mode(sc, n_ticks, True, repeats=1)
    tps = warp_row["ticks_per_s"]
    assert warp_res["unfinished"] == 0, warp_res
    assert tps >= floor, (
        f"scale-smoke FAILED: warm time-warp fabric ran {tps:,.0f} ticks/s "
        f"< floor {floor:,.0f} on the 512-host permutation")
    print(f"scale-smoke ok: 512 hosts, warp {tps:,.0f} ticks/s "
          f"(floor {floor:,.0f}), compile {warp_row['compile_s']:.2f}s, "
          f"{warp_row['warp_trips']} trips")


def profile_scenario(trace_dir: str, name: str = "perm1024",
                     kernel_backend: str = "jnp") -> None:
    """One warp scenario under ``jax.profiler.trace`` (``make profile``).

    Compiles OUTSIDE the trace (a cold run first), then traces warm
    warp run(s), so the trace shows the scan body — the thing the Pallas
    kernels target — not XLA compilation.  View with
    ``tensorboard --logdir <trace_dir>`` (or ``xprof``)."""
    sc, cfg_kw = canonical_scenarios()[name]
    n_ticks = sc.default_ticks()
    cfg = RunConfig(backend="fabric", time_warp=True, trace_every=0,
                    n_ticks=n_ticks, kernel_backend=kernel_backend,
                    **cfg_kw)
    run(sc, cfg)                           # compile outside the trace
    with jax.profiler.trace(trace_dir):
        t0 = time.perf_counter()
        res = run(sc, cfg)
        run_s = time.perf_counter() - t0
    print(f"profile[{name}, {kernel_backend}]: {n_ticks} ticks in "
          f"{run_s:.3f}s warm ({n_ticks / run_s:,.0f} t/s, "
          f"{res.get('warp_trips')} trips) -> {trace_dir}")
    print(f"view with: tensorboard --logdir {trace_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_fabric.json")
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--smoke", action="store_true",
                    help="2k-tick ticks/sec floor canary (CI)")
    ap.add_argument("--scale", action="store_true",
                    help="512-host warp ticks/sec floor point (CI)")
    ap.add_argument("--floor", type=float, default=None)
    ap.add_argument("--check", metavar="PATH",
                    help="validate an existing BENCH_fabric.json (schema "
                         "+ parity gate) without running anything")
    ap.add_argument("--kernel-backends", metavar="LIST", default=None,
                    help="comma list of kernel backends to sweep "
                         "(default: jnp + pallas_interpret on CPU, "
                         "jnp + pallas elsewhere); 'jnp' alone skips "
                         "the kernels axis")
    ap.add_argument("--profile", metavar="DIR",
                    help="trace one warm warp scenario under "
                         "jax.profiler.trace into DIR and exit")
    ap.add_argument("--profile-scenario", default="perm1024",
                    choices=sorted(canonical_scenarios()),
                    help="which canonical scenario --profile runs")
    args = ap.parse_args()
    backends = (None if args.kernel_backends is None
                else [b for b in args.kernel_backends.split(",") if b])
    if args.check:
        sys.exit(check_report_file(args.check))
    if args.profile:
        kb = next((b for b in (backends or []) if b != "jnp"), None)
        profile_scenario(args.profile, name=args.profile_scenario,
                         kernel_backend=kb or "jnp")
        return
    if args.smoke:
        smoke(floor=args.floor if args.floor is not None
              else SMOKE_FLOOR_TICKS_PER_S)
        return
    if args.scale:
        scale_smoke(floor=args.floor if args.floor is not None
                    else SCALE_FLOOR_TICKS_PER_S)
        return
    bench_all(args.out, repeats=args.repeats, kernel_backends=backends)


if __name__ == "__main__":
    main()
