"""2k-tick fabric smoke run — catches perf regressions on the jitted path.

Runs a 16-host permutation on the 4x4 multi-queue fabric twice (cold =
compile + run, warm = run only) and prints wall times and warm ticks/sec —
once per protocol: the STrack fast path AND the ported RoCEv2 (DCQCN +
go-back-N + PFC) baseline, so a regression in either leg fails CI fast.
``make smoke`` chains this after the tier-1 tests.

    PYTHONPATH=src python -m benchmarks.fabric_smoke [n_ticks] [protocol]

``protocol`` is ``strack``, ``rocev2`` or ``all`` (default).
"""
from __future__ import annotations

import sys
import time

from repro.core.params import NetworkSpec
from repro.sim.fabric import FabricConfig, run_fabric, summarize
from repro.sim.topology import full_bisection
from repro.sim.workloads import permutation_scenario


def run_one(protocol: str, n_ticks: int) -> None:
    sc = permutation_scenario(full_bisection(4, 4), 64 * 2 ** 10,
                              net=NetworkSpec())
    cfg = FabricConfig(net=sc.net, protocol=protocol)
    t0 = time.time()
    _, m = run_fabric(sc.topo, sc.flows, n_ticks, cfg)
    cold_s = time.time() - t0
    t0 = time.time()
    _, m = run_fabric(sc.topo, sc.flows, n_ticks, cfg)
    warm_s = time.time() - t0
    s = summarize(m)
    assert s["unfinished"] == 0, s
    assert s["drops"] == 0, s
    if protocol == "rocev2":
        # lossless canary: this light permutation must neither pause (a
        # nonzero count here means the PFC accounting leaked) nor stall
        # (go-back-N/DCQCN livelock would blow the FCT out)
        assert s["pauses"] == 0, s
        assert s["max_fct"] < 50.0, s
    print(f"fabric-smoke[{protocol}] ok: {n_ticks} ticks x 16 flows on 4x4 "
          f"fat-tree | cold {cold_s:.2f}s (jit+run), warm {warm_s:.2f}s "
          f"({n_ticks / warm_s:,.0f} ticks/s) | "
          f"max_fct {s['max_fct']:.1f}us pauses {s['pauses']}")


def main(n_ticks: int = 2000, protocol: str = "all") -> None:
    for proto in (("strack", "rocev2") if protocol == "all"
                  else (protocol,)):
        run_one(proto, n_ticks)


if __name__ == "__main__":
    main(int(sys.argv[1]) if len(sys.argv) > 1 else 2000,
         sys.argv[2] if len(sys.argv) > 2 else "all")
