"""Paper Figs. 12-15: permutation under 4:1/8:1 oversubscription and under
link failures (asymmetric network).

Validates: STrack's joint CC+LB keeps winning (up to 3x / 6x in the paper);
adaptive spray beats oblivious especially with failed links (60% in paper).

All transports run on the jitted multi-queue fabric through the one
experiment API: STrack spray variants (adaptive / oblivious / fixed-path
pinning), the RoCEv2/DCQCN/PFC baseline AND the 4-QP striped RoCEv2
variant.  The scenario objects are shared, so every leg sees the same
flows on the same (oversubscribed / dead-link) topology.  Pass
``backend="events"`` to fall back to the oracle.

The link-failure legs now run through the chaos subsystem
(``sim/faults.py``): the static dead-link matrix is expressed as the
degenerate t=0 flap schedule (``faults_from_dead_links``) on a fully
alive topology, and a mid-run flap leg (``run_flap``) exercises a link
going down and RECOVERING while the permutation is in flight.
``--chaos-smoke`` (the ``make chaos-smoke`` target) gates the chaos
path: the t=0 schedule must reproduce the native dead-link results
bit-exactly, the mid-run flap must drain with nonzero recovery
counters, and a chaos soak must compile exactly one program.
"""
from __future__ import annotations

import sys
from dataclasses import replace

from repro.core.params import NetworkSpec
from repro.sim.faults import faults_from_dead_links, link_flap
from repro.sim.topology import full_bisection, with_link_failures
from repro.sim.workloads import (linkdown_scenario, oversub_scenario,
                                 permutation_scenario)

from .common import (FABRIC_TRANSPORTS, QUICK_TOPO, run_events_transport,
                     run_transport, timed)


def _run_matrix(sc, fig: str, workload: str, msg: float, seed: int,
                until: float = 1e6, backend: str = "fabric"):
    rows = []
    fcts = {}
    for tr in FABRIC_TRANSPORTS:
        if backend == "fabric":
            res, wall = timed(run_transport, tr, sc, backend="fabric")
        elif tr == "strack-fixed":
            continue  # single-path pinning only exists on the fabric
        else:
            (res, _), wall = timed(run_events_transport, tr, sc,
                                   until=until, seed=seed)
        fcts[tr] = res["max_fct"]
        rows.append({"fig": fig, "workload": workload, "msg": msg,
                     "transport": tr,
                     "backend": res.get("backend", "events"),
                     "max_fct_us": res["max_fct"], "drops": res["drops"],
                     "unfinished": res["unfinished"], "wall_s": wall})
    rows[-1]["speedup_vs_roce"] = fcts["roce"] / fcts["strack"]
    rows[-1]["adaptive_vs_oblivious"] = fcts["strack-obl"] / fcts["strack"]
    if "strack-fixed" in fcts:
        rows[-1]["adaptive_vs_fixed"] = (fcts["strack-fixed"]
                                         / fcts["strack"])
    return rows


def run_oversub(ratio: int = 4, msg: float = 512 * 2 ** 10,
                topo_kw=None, seed: int = 0):
    # keep >=2 spines so multipath exists at high oversubscription
    topo_kw = topo_kw or dict(n_tor=4, hosts_per_tor=max(8, 2 * ratio))
    sc = oversub_scenario(topo_kw["n_tor"], topo_kw["hosts_per_tor"], ratio,
                          msg, net=NetworkSpec(), seed=seed)
    return _run_matrix(sc, "12-13", f"oversub_{ratio}:1", msg, seed)


def run_linkdown(frac_links_down: float = 0.125,
                 msg: float = 512 * 2 ** 10, topo_kw=None, seed: int = 0,
                 chaos: bool = True):
    """Figs 14-15 leg.  ``chaos=True`` (default) expresses the dead-link
    matrix as a t=0 flap schedule on a fully-alive topology — same flows,
    same live uplinks at every tick, exercised through the time-varying
    fault path; ``chaos=False`` keeps the native ``dead_links`` route."""
    topo_kw = topo_kw or QUICK_TOPO
    sc = linkdown_scenario(topo_kw, frac_links_down, msg,
                           net=NetworkSpec(), seed=seed)
    if chaos:
        sc = replace(sc, topo=full_bisection(**topo_kw),
                     faults=faults_from_dead_links(sc.topo))
    return _run_matrix(sc, "14-15", sc.name, msg, seed)


def run_flap(msg: float = 512 * 2 ** 10, topo_kw=None, seed: int = 0,
             t0: int = 50, t1: int = 400):
    """Mid-run flap leg: one uplink of ToR 0 goes down at ``t0`` and
    RECOVERS at ``t1`` while the permutation is in flight — the loss-
    recovery path (RTO / SACK / go-back-N) every transport must survive."""
    topo_kw = topo_kw or QUICK_TOPO
    sc = permutation_scenario(full_bisection(**topo_kw), msg,
                              net=NetworkSpec(), seed=seed)
    sc = replace(sc, name=f"flap_{t0}_{t1}",
                 faults=link_flap(0, 0, t0, t1))
    rows = _run_matrix(sc, "14-15*", sc.name, msg, seed)
    for r in rows:
        res = run_transport(r["transport"], sc, backend="fabric")
        r["rto_fires"] = res["rto_fires"]
        r["sack_recoveries"] = res["sack_recoveries"]
        r["gbn_rewinds"] = res["gbn_rewinds"]
        r["blackholed_pkts"] = res["blackholed_pkts"]
    return rows


def chaos_smoke(msg: float = 128 * 2 ** 10, seed: int = 0) -> int:
    """CI gate for the chaos path (``make chaos-smoke``).  Checks:

    1. the degenerate t=0 flap schedule reproduces the native dead-link
       results bit-exactly (same flows, same routing, same FCTs);
    2. the mid-run flap leg drains on every transport with nonzero
       blackholes and nonzero recovery activity;
    3. a chaos soak (clean + flapped epochs) compiles exactly ONE
       program and reports per-tenant degradation.
    """
    problems = []
    topo_kw = QUICK_TOPO
    # -- gate 1: static dead links == t=0 chaos schedule, bit-exact ------ #
    sc_nat = linkdown_scenario(topo_kw, 0.25, msg, net=NetworkSpec(),
                               seed=seed)
    sc_cha = replace(sc_nat, topo=full_bisection(**topo_kw),
                     faults=faults_from_dead_links(sc_nat.topo))
    for tr in ("strack", "roce"):
        nat = run_transport(tr, sc_nat, backend="fabric")
        cha = run_transport(tr, sc_cha, backend="fabric")
        for k in ("max_fct", "avg_fct", "unfinished", "drops", "pauses"):
            if nat[k] != cha[k]:
                problems.append(
                    f"gate1[{tr}]: {k} native={nat[k]} chaos={cha[k]} "
                    f"(t=0 schedule must be bit-exact vs dead_links)")
        if cha["blackholed_pkts"] != 0:
            problems.append(
                f"gate1[{tr}]: {cha['blackholed_pkts']} blackholed pkts "
                f"(ECMP must steer off down links, not feed them)")
        print(f"chaos-smoke gate1[{tr}]: native max_fct {nat['max_fct']:.2f}"
              f"us == chaos {cha['max_fct']:.2f}us")
    # -- gate 2: mid-run flap drains with recovery activity -------------- #
    # Drain is per-transport; loss/recovery is aggregate — ECMP leaves the
    # flapped uplink the tick it goes down, so a single-path transport can
    # legitimately lose only what was already queued on it (possibly 0).
    tot_bh = tot_recov = 0
    for r in run_flap(msg=msg, topo_kw=topo_kw, seed=seed):
        tr = r["transport"]
        if r["unfinished"]:
            problems.append(f"gate2[{tr}]: {r['unfinished']} unfinished "
                            f"flows under a mid-run flap")
        recov = r["rto_fires"] + r["sack_recoveries"] + r["gbn_rewinds"]
        tot_bh += r["blackholed_pkts"]
        tot_recov += recov
        print(f"chaos-smoke gate2[{tr}]: max_fct {r['max_fct_us']:.2f}us, "
              f"blackholed {r['blackholed_pkts']}, recoveries {recov}")
    if tot_bh == 0:
        problems.append("gate2: flap overlapped live flows but no "
                        "transport blackholed a single pkt")
    if tot_recov == 0:
        problems.append("gate2: flap lost pkts but no recovery counter "
                        "fired on any transport")
    # -- gate 3: chaos soak compiles one program ------------------------- #
    from repro.sim.traffic import InferenceTenant, TrainingJob, soak
    topo = full_bisection(**topo_kw)
    res = soak(topo,
               [TrainingJob(name="train0", algo="ring", ranks=8,
                            collective_bytes=64 * 2 ** 10, steps=2)],
               [InferenceTenant(name="infer0", n_flows=16)],
               epochs=3, seed=seed,
               chaos=[None, link_flap(0, 0, 10, 120), None])
    if res["program_builds"] > 1:
        problems.append(f"gate3: chaos soak compiled "
                        f"{res['program_builds']} programs, expected 1")
    if res["totals"]["unfinished"]:
        problems.append(f"gate3: chaos soak left "
                        f"{res['totals']['unfinished']} messages unfinished")
    degr = {k: v.get("degradation_p99") for k, v in
            res["per_tenant"].items()}
    if not any(d == d and d > 0 for d in degr.values()):
        problems.append(f"gate3: no per-tenant degradation ratio computed "
                        f"({degr})")
    print(f"chaos-smoke gate3: program_builds {res['program_builds']}, "
          f"degradation {dict((k, round(v, 2)) for k, v in degr.items())}")
    for p in problems:
        print(f"CHAOS-SMOKE FAIL: {p}")
    return 1 if problems else 0


def main():
    if "--chaos-smoke" in sys.argv:
        raise SystemExit(chaos_smoke())
    for r in run_oversub(4) + run_oversub(8) + run_linkdown(0.0625) \
            + run_linkdown(0.25) + run_flap():
        print(r)


if __name__ == "__main__":
    main()
