"""Paper Figs. 12-15: permutation under 4:1/8:1 oversubscription and under
link failures (asymmetric network).

Validates: STrack's joint CC+LB keeps winning (up to 3x / 6x in the paper);
adaptive spray beats oblivious especially with failed links (60% in paper).
"""
from __future__ import annotations

from repro.core.params import NetworkSpec
from repro.sim.topology import full_bisection, oversubscribed, \
    with_link_failures
from repro.sim.workloads import run_permutation

from .common import QUICK_TOPO, TRANSPORTS, make_sim, timed


def run_oversub(ratio: int = 4, msg: float = 512 * 2 ** 10,
                topo_kw=None, seed: int = 0):
    # keep >=2 spines so multipath exists at high oversubscription
    topo_kw = topo_kw or dict(n_tor=4, hosts_per_tor=max(8, 2 * ratio))
    rows = []
    fcts = {}
    for tr in TRANSPORTS:
        net = NetworkSpec()
        topo = oversubscribed(topo_kw["n_tor"], topo_kw["hosts_per_tor"],
                              ratio)
        sim = make_sim(tr, topo, net, seed=seed)
        res, wall = timed(run_permutation, sim, msg, seed=seed, until=1e6)
        fcts[tr] = res["max_fct"]
        rows.append({"fig": "12-13", "workload": f"oversub_{ratio}:1",
                     "msg": msg, "transport": tr,
                     "max_fct_us": res["max_fct"], "drops": res["drops"],
                     "unfinished": res["unfinished"], "wall_s": wall})
    rows[-1]["speedup_vs_roce"] = fcts["roce"] / fcts["strack"]
    return rows


def run_linkdown(frac_links_down: float = 0.125,
                 msg: float = 512 * 2 ** 10, topo_kw=None, seed: int = 0):
    topo_kw = topo_kw or QUICK_TOPO
    base = full_bisection(**topo_kw)
    n_links = base.n_tor * base.n_spine
    n_down = max(1, int(frac_links_down * n_links))
    rows = []
    fcts = {}
    for tr in TRANSPORTS:
        net = NetworkSpec()
        topo = with_link_failures(base, n_down,
                                  n_tors_affected=max(1, base.n_tor // 2),
                                  seed=seed)
        sim = make_sim(tr, topo, net, seed=seed)
        res, wall = timed(run_permutation, sim, msg, seed=seed, until=1e6)
        fcts[tr] = res["max_fct"]
        rows.append({"fig": "14-15", "workload": f"linkdown_{n_down}",
                     "msg": msg, "transport": tr,
                     "max_fct_us": res["max_fct"], "drops": res["drops"],
                     "unfinished": res["unfinished"], "wall_s": wall})
    rows[-1]["speedup_vs_roce"] = fcts["roce"] / fcts["strack"]
    rows[-1]["adaptive_vs_oblivious"] = fcts["strack-obl"] / fcts["strack"]
    return rows


def main():
    for r in run_oversub(4) + run_oversub(8) + run_linkdown(0.0625) \
            + run_linkdown(0.25):
        print(r)


if __name__ == "__main__":
    main()
