"""Paper Figs. 12-15: permutation under 4:1/8:1 oversubscription and under
link failures (asymmetric network).

Validates: STrack's joint CC+LB keeps winning (up to 3x / 6x in the paper);
adaptive spray beats oblivious especially with failed links (60% in paper).

All transports run on the jitted multi-queue fabric through the one
experiment API: STrack spray variants (adaptive / oblivious / fixed-path
pinning), the RoCEv2/DCQCN/PFC baseline AND the 4-QP striped RoCEv2
variant.  The scenario objects are shared, so every leg sees the same
flows on the same (oversubscribed / dead-link) topology.  Pass
``backend="events"`` to fall back to the oracle.
"""
from __future__ import annotations

from repro.core.params import NetworkSpec
from repro.sim.workloads import linkdown_scenario, oversub_scenario

from .common import (FABRIC_TRANSPORTS, QUICK_TOPO, run_events_transport,
                     run_transport, timed)


def _run_matrix(sc, fig: str, workload: str, msg: float, seed: int,
                until: float = 1e6, backend: str = "fabric"):
    rows = []
    fcts = {}
    for tr in FABRIC_TRANSPORTS:
        if backend == "fabric":
            res, wall = timed(run_transport, tr, sc, backend="fabric")
        elif tr == "strack-fixed":
            continue  # single-path pinning only exists on the fabric
        else:
            (res, _), wall = timed(run_events_transport, tr, sc,
                                   until=until, seed=seed)
        fcts[tr] = res["max_fct"]
        rows.append({"fig": fig, "workload": workload, "msg": msg,
                     "transport": tr,
                     "backend": res.get("backend", "events"),
                     "max_fct_us": res["max_fct"], "drops": res["drops"],
                     "unfinished": res["unfinished"], "wall_s": wall})
    rows[-1]["speedup_vs_roce"] = fcts["roce"] / fcts["strack"]
    rows[-1]["adaptive_vs_oblivious"] = fcts["strack-obl"] / fcts["strack"]
    if "strack-fixed" in fcts:
        rows[-1]["adaptive_vs_fixed"] = (fcts["strack-fixed"]
                                         / fcts["strack"])
    return rows


def run_oversub(ratio: int = 4, msg: float = 512 * 2 ** 10,
                topo_kw=None, seed: int = 0):
    # keep >=2 spines so multipath exists at high oversubscription
    topo_kw = topo_kw or dict(n_tor=4, hosts_per_tor=max(8, 2 * ratio))
    sc = oversub_scenario(topo_kw["n_tor"], topo_kw["hosts_per_tor"], ratio,
                          msg, net=NetworkSpec(), seed=seed)
    return _run_matrix(sc, "12-13", f"oversub_{ratio}:1", msg, seed)


def run_linkdown(frac_links_down: float = 0.125,
                 msg: float = 512 * 2 ** 10, topo_kw=None, seed: int = 0):
    topo_kw = topo_kw or QUICK_TOPO
    sc = linkdown_scenario(topo_kw, frac_links_down, msg,
                           net=NetworkSpec(), seed=seed)
    return _run_matrix(sc, "14-15", sc.name, msg, seed)


def main():
    for r in run_oversub(4) + run_oversub(8) + run_linkdown(0.0625) \
            + run_linkdown(0.25):
        print(r)


if __name__ == "__main__":
    main()
