"""`make soak`: sustained multi-tenant operation of the warp fabric.

Runs the observatory's mixed workload — ≥2 overlapping training jobs
(dependency-chained collectives on fixed placements, staggered starts)
plus an open-loop inference/incast burst tenant — on a 64-host fabric
for ≥10 warp epochs, carrying drop/pause/ECN/retransmit counters across
epochs, and writes the Prometheus text exposition (``BENCH_soak.prom``)
that ``make serve-metrics`` serves.

Gates (non-zero exit on any failure):

  * every epoch drains (``unfinished == 0``) and the whole soak reuses
    ONE compiled fabric program (epoch traces are structure-identical);
  * the written ``.prom`` file round-trips through
    ``repro.obs.metrics.parse_prometheus``;
  * per-tenant FCT percentiles (p50, p99) from the fabric's
    ``tenant_fct`` attribution sit within the fuzz parity band
    (``SPOT_BAND``) of an events-oracle run of the same small-config
    mix.

    PYTHONPATH=src python -m benchmarks.soak [--out BENCH_soak.prom]
    PYTHONPATH=src python -m benchmarks.soak --smoke   # CI: 3 epochs
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core.params import NetworkSpec
from repro.obs.metrics import MetricsRegistry, parse_prometheus, \
    render_prometheus
from repro.sim.topology import full_bisection
from repro.sim.traffic import InferenceTenant, TrainingJob, mixed_scenario, \
    soak
from repro.sim.workloads import RunConfig, run

#: Fabric-vs-oracle band for the per-tenant FCT spot check — the
#: differential-fuzz band (benchmarks/perf.py SPOT_BAND).
SPOT_BAND = (0.7, 1.4)


def default_fleet():
    """The ≥64-host production mix: two training jobs + a burst tenant."""
    topo = full_bisection(8, 8)          # 64 hosts, 8 ToRs, 8 spines
    net = NetworkSpec(link_gbps=400.0)
    jobs = [
        TrainingJob("train_ring", algo="ring", ranks=16,
                    collective_bytes=256 * 2 ** 10, steps=2,
                    algo_kw=(("chunk", 64 * 2 ** 10),)),
        TrainingJob("train_hd", algo="hd", ranks=16,
                    collective_bytes=256 * 2 ** 10, steps=2,
                    start_tick=64),
    ]
    tenants = [
        InferenceTenant("inference", n_flows=128,
                        mean_interarrival_ticks=4.0,
                        size_bytes=16 * 2 ** 10, size_jitter=0.5,
                        n_targets=4),
    ]
    return topo, net, jobs, tenants


def spot_fleet():
    """Small config for the events-oracle spot check (oracle wall-clock
    scales with packet count, so this stays 16 hosts / tens of flows)."""
    topo = full_bisection(4, 4)
    net = NetworkSpec(link_gbps=400.0)
    jobs = [
        TrainingJob("train_ring", algo="ring", ranks=4,
                    collective_bytes=128 * 2 ** 10),
        TrainingJob("train_hd", algo="hd", ranks=4,
                    collective_bytes=128 * 2 ** 10, start_tick=32),
    ]
    tenants = [
        InferenceTenant("inference", n_flows=24,
                        mean_interarrival_ticks=6.0,
                        size_bytes=16 * 2 ** 10, n_targets=2),
    ]
    return topo, net, jobs, tenants


def _events_tenant_fct(sc) -> dict:
    """Per-group FCT percentiles from the events oracle's msg_fct map."""
    res = run(sc, RunConfig(backend="events", until=2e7))
    msg_fct = res["msg_fct"]
    by_g: dict = {}
    for m in sc.messages:
        by_g.setdefault(m.group, []).append(msg_fct.get(m.mid))
    rows = {}
    for g, fs in by_g.items():
        done = [f for f in fs if f is not None]
        rows[g] = {
            "count": len(fs), "unfinished": len(fs) - len(done),
            "p50": float(np.percentile(done, 50)) if done else float("nan"),
            "p99": float(np.percentile(done, 99)) if done else float("nan"),
        }
    return rows


def tenant_spot_check(seed: int = 0, band=SPOT_BAND) -> list:
    """Fabric-vs-oracle per-tenant FCT parity on the small mix.

    Returns a list of human-readable problems (empty = within band)."""
    topo, net, jobs, tenants = spot_fleet()
    sc, tenant_of_group = mixed_scenario(topo, jobs, tenants, net=net,
                                         seed=seed, epoch=0)
    fb = run(sc, RunConfig())
    ev = _events_tenant_fct(sc)
    problems = []
    if fb["unfinished"]:
        problems.append(f"spot: fabric left {fb['unfinished']} messages "
                        f"unfinished")
    for g, name in sorted(tenant_of_group.items()):
        frow, erow = fb["tenant_fct"][g], ev[g]
        if erow["unfinished"]:
            problems.append(f"spot[{name}]: oracle left "
                            f"{erow['unfinished']} messages unfinished")
            continue
        for q in ("p50", "p99"):
            ratio = frow[q] / erow[q]
            ok = band[0] < ratio < band[1]
            print(f"spot[{name}] {q}: fabric {frow[q]:.2f}us vs oracle "
                  f"{erow[q]:.2f}us (ratio {ratio:.3f}, "
                  f"{'ok' if ok else 'OUT OF BAND'})")
            if not ok:
                problems.append(
                    f"spot[{name}]: {q} ratio {ratio:.3f} outside "
                    f"{band} (fabric {frow[q]:.2f}us, oracle "
                    f"{erow[q]:.2f}us)")
    return problems


def run_soak(out_path: str, epochs: int, seed: int = 0,
             n_ticks=None, smoke: bool = False) -> int:
    """Drive the soak + gates; returns a process exit code."""
    if smoke:
        topo, net, jobs, tenants = spot_fleet()
    else:
        topo, net, jobs, tenants = default_fleet()
    reg = MetricsRegistry()
    t0 = time.perf_counter()
    res = soak(topo, jobs, tenants, epochs=epochs, net=net, seed=seed,
               n_ticks=n_ticks, registry=reg, out_path=out_path,
               verbose=True)
    wall = time.perf_counter() - t0
    with open(out_path, "w") as f:
        f.write(render_prometheus(reg))
    print(f"soak: {epochs} epochs x {res['n_ticks']} ticks on "
          f"{topo.n_hosts} hosts in {wall:.1f}s "
          f"({res['totals']['messages']} messages, "
          f"{res['program_builds']} program build(s)) -> {out_path}")
    problems = []
    if res["totals"]["unfinished"]:
        problems.append(f"soak: {res['totals']['unfinished']} messages "
                        f"never finished")
    if res["program_builds"] > 1:
        problems.append(
            f"soak: {res['program_builds']} program builds across "
            f"{epochs} structure-identical epochs — the epoch traces "
            f"stopped hitting the program cache")
    # the .prom file must be real Prometheus text format
    try:
        parsed = parse_prometheus(open(out_path).read())
        assert parsed[("strack_epochs_total", ())] == float(epochs)
        print(f"soak: {out_path} round-trips the exposition parser "
              f"({len(parsed)} samples)")
    except (OSError, ValueError, KeyError, AssertionError) as e:
        problems.append(f"soak: {out_path} failed the exposition "
                        f"round-trip: {e!r}")
    problems += tenant_spot_check(seed=seed)
    for p in problems:
        print(f"soak gate: {p}", file=sys.stderr)
    return 1 if problems else 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_soak.prom")
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: small fleet, 3 epochs of 2000 ticks")
    args = ap.parse_args()
    if args.smoke:
        epochs = args.epochs or 3
        sys.exit(run_soak(args.out, epochs, seed=args.seed,
                          n_ticks=2000, smoke=True))
    epochs = args.epochs or 10
    sys.exit(run_soak(args.out, epochs, seed=args.seed))


if __name__ == "__main__":
    main()
