"""Paper Figs. 8-11: permutation max-FCT vs message size, per link speed.

Validates: STrack >> RoCEv2 (up to 6.3x in the paper at 8K nodes), adaptive
spray > oblivious spray for large messages, and queue-delay settling
(Fig. 8).  Reduced scale: 16-256 hosts vs the paper's 8192.

STrack spray variants (adaptive / oblivious / fixed-path) run on the jitted
multi-queue fabric (``repro.sim.fabric``) — one XLA program per run; the
RoCEv2 baselines run on the event oracle (PFC/go-back-N only exist there).
Pass ``backend="events"`` to run everything on the oracle instead.
"""
from __future__ import annotations

from repro.core.params import NetworkSpec
from repro.sim.topology import full_bisection
from repro.sim.workloads import permutation_scenario

from .common import (FABRIC_LB, MSG_SIZES_QUICK, QUICK_TOPO, TRANSPORTS,
                     run_events_transport, run_fabric_transport, timed)


def run(quick: bool = True, link_gbps: float = 400.0, msg_sizes=None,
        topo_kw=None, seed: int = 0, trace_queues: bool = False,
        backend: str = "fabric"):
    topo_kw = topo_kw or QUICK_TOPO
    msg_sizes = msg_sizes or MSG_SIZES_QUICK
    rows = []
    for msg in msg_sizes:
        net = NetworkSpec(link_gbps=link_gbps)
        topo = full_bisection(**topo_kw)
        sc = permutation_scenario(topo, msg, net=net, seed=seed)
        fcts = {}
        transports = (list(FABRIC_LB) + ["roce", "roce4"]
                      if backend == "fabric" else TRANSPORTS)
        for tr in transports:
            if backend == "fabric" and tr in FABRIC_LB:
                res, wall = timed(run_fabric_transport, tr, sc)
                queue_settle = None
            else:
                (res, sim), wall = timed(run_events_transport, tr, sc,
                                         until=5e5, seed=seed,
                                         log_queues=trace_queues)
                queue_settle = (max((t for t, d in
                                     sim.all_queue_delay_logs()),
                                    default=0.0)
                                if trace_queues else None)
            fcts[tr] = res["max_fct"]
            rows.append({
                "fig": "9-11", "workload": "permutation",
                "backend": res.get("backend", "events"),
                "link_gbps": link_gbps, "msg": msg, "transport": tr,
                "max_fct_us": res["max_fct"], "avg_fct_us": res["avg_fct"],
                "drops": res["drops"], "unfinished": res["unfinished"],
                "wall_s": wall,
                "queue_settle_us": queue_settle,
            })
        rows[-1]["speedup_vs_roce"] = fcts["roce"] / fcts["strack"]
        rows[-1]["adaptive_vs_oblivious"] = (fcts["strack-obl"]
                                             / fcts["strack"])
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--link-gbps", type=float, default=400.0)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--trace-queues", action="store_true")
    ap.add_argument("--backend", choices=["fabric", "events"],
                    default="fabric")
    args = ap.parse_args()
    from .common import FULL_TOPO, MSG_SIZES_FULL
    rows = run(quick=not args.full, link_gbps=args.link_gbps,
               msg_sizes=MSG_SIZES_FULL if args.full else None,
               topo_kw=FULL_TOPO if args.full else None,
               trace_queues=args.trace_queues, backend=args.backend)
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
