"""Paper Figs. 8-11: permutation max-FCT vs message size, per link speed.

Validates: STrack >> RoCEv2 (up to 6.3x in the paper at 8K nodes), adaptive
spray > oblivious spray for large messages, and queue-delay settling
(Fig. 8).  Reduced scale: 16-256 hosts vs the paper's 8192.
"""
from __future__ import annotations

from repro.core.params import NetworkSpec
from repro.sim.topology import full_bisection
from repro.sim.workloads import run_permutation

from .common import (MSG_SIZES_QUICK, QUICK_TOPO, TRANSPORTS, make_sim,
                     timed)


def run(quick: bool = True, link_gbps: float = 400.0, msg_sizes=None,
        topo_kw=None, seed: int = 0, trace_queues: bool = False):
    topo_kw = topo_kw or QUICK_TOPO
    msg_sizes = msg_sizes or MSG_SIZES_QUICK
    rows = []
    for msg in msg_sizes:
        fcts = {}
        for tr in TRANSPORTS:
            net = NetworkSpec(link_gbps=link_gbps)
            topo = full_bisection(**topo_kw)
            sim = make_sim(tr, topo, net, log_queues=trace_queues,
                           seed=seed)
            res, wall = timed(run_permutation, sim, msg, seed=seed,
                              until=5e5)
            fcts[tr] = res["max_fct"]
            rows.append({
                "fig": "9-11", "workload": "permutation",
                "link_gbps": link_gbps, "msg": msg, "transport": tr,
                "max_fct_us": res["max_fct"], "avg_fct_us": res["avg_fct"],
                "drops": res["drops"], "unfinished": res["unfinished"],
                "wall_s": wall,
                "queue_settle_us": (max((t for t, d in
                                         sim.all_queue_delay_logs()),
                                        default=0.0)
                                    if trace_queues else None),
            })
        rows[-1]["speedup_vs_roce"] = fcts["roce"] / fcts["strack"]
        rows[-1]["adaptive_vs_oblivious"] = (fcts["strack-obl"]
                                             / fcts["strack"])
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--link-gbps", type=float, default=400.0)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--trace-queues", action="store_true")
    args = ap.parse_args()
    from .common import FULL_TOPO, MSG_SIZES_FULL
    rows = run(quick=not args.full, link_gbps=args.link_gbps,
               msg_sizes=MSG_SIZES_FULL if args.full else None,
               topo_kw=FULL_TOPO if args.full else None,
               trace_queues=args.trace_queues)
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
