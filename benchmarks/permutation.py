"""Paper Figs. 8-11: permutation max-FCT vs message size, per link speed.

Validates: STrack >> RoCEv2 (up to 6.3x in the paper at 8K nodes), adaptive
spray > oblivious spray for large messages, and queue-delay settling
(Fig. 8).  Reduced scale: 16-256 hosts vs the paper's 8192.

EVERY leg of the figure runs on the jitted multi-queue fabric
(``repro.sim.fabric``) through the one experiment API: STrack spray
variants, the RoCEv2/DCQCN/PFC baseline AND the 4-QP striped RoCEv2
variant (``subflows=4`` message striping) — one XLA program per
(transport, message size), with a vmap-over-seeds ``sweep()`` batching
``--seeds`` repetitions into a single jit.  Pass ``backend="events"`` to
run everything on the oracle instead.
"""
from __future__ import annotations

from repro.core.params import NetworkSpec
from repro.sim.topology import full_bisection
from repro.sim.workloads import permutation_scenario

from .common import (FABRIC_TRANSPORTS, MSG_SIZES_QUICK, QUICK_TOPO,
                     TRANSPORTS, run_events_transport, sweep_transport,
                     timed)


def _agg_seeds(per_seed: list) -> dict:
    """Collapse a seed sweep into one row: mean FCTs/drops across seeds
    (the per-seed values ride along under ``*_seeds``)."""
    n = len(per_seed)
    out = dict(per_seed[0])
    for k in ("max_fct", "avg_fct", "drops", "pauses"):
        out[k] = sum(r[k] for r in per_seed) / n
    out["unfinished"] = sum(r["unfinished"] for r in per_seed)
    out["max_fct_seeds"] = [r["max_fct"] for r in per_seed]
    if "queue_settle_us" in per_seed[0]:
        out["queue_settle_us"] = max(r["queue_settle_us"] for r in per_seed)
    return out


def run(quick: bool = True, link_gbps: float = 400.0, msg_sizes=None,
        topo_kw=None, seed: int = 0, trace_queues: bool = False,
        backend: str = "fabric", seeds: int = 1):
    topo_kw = topo_kw or QUICK_TOPO
    msg_sizes = msg_sizes or MSG_SIZES_QUICK
    rows = []
    for msg in msg_sizes:
        net = NetworkSpec(link_gbps=link_gbps)
        topo = full_bisection(**topo_kw)
        sc = permutation_scenario(topo, msg, net=net, seed=seed)
        fcts = {}
        transports = (FABRIC_TRANSPORTS if backend == "fabric"
                      else TRANSPORTS)
        for tr in transports:
            if backend == "fabric":
                scs = [permutation_scenario(topo, msg, net=net,
                                            seed=seed + i)
                       for i in range(seeds)]
                per_seed, wall = timed(sweep_transport, tr, scs,
                                       trace_queues=trace_queues)
                res = _agg_seeds(per_seed)
                queue_settle = res.get("queue_settle_us")
            else:
                (res, sim), wall = timed(run_events_transport, tr, sc,
                                         until=5e5, seed=seed,
                                         log_queues=trace_queues)
                queue_settle = (max((t for t, d in
                                     sim.all_queue_delay_logs()),
                                    default=0.0)
                                if trace_queues else None)
            fcts[tr] = res["max_fct"]
            rows.append({
                "fig": "9-11", "workload": "permutation",
                "backend": res.get("backend", "events"),
                "link_gbps": link_gbps, "msg": msg, "transport": tr,
                "seeds": seeds if tr in FABRIC_TRANSPORTS else 1,
                "max_fct_us": res["max_fct"], "avg_fct_us": res["avg_fct"],
                "drops": res["drops"], "unfinished": res["unfinished"],
                "wall_s": wall,
                "queue_settle_us": queue_settle,
            })
        rows[-1]["speedup_vs_roce"] = fcts["roce"] / fcts["strack"]
        rows[-1]["adaptive_vs_oblivious"] = (fcts["strack-obl"]
                                             / fcts["strack"])
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--link-gbps", type=float, default=400.0)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--trace-queues", action="store_true")
    ap.add_argument("--backend", choices=["fabric", "events"],
                    default="fabric")
    ap.add_argument("--seeds", type=int, default=1,
                    help="vmap this many seeds per fabric run")
    args = ap.parse_args()
    from .common import FULL_TOPO, MSG_SIZES_FULL
    rows = run(quick=not args.full, link_gbps=args.link_gbps,
               msg_sizes=MSG_SIZES_FULL if args.full else None,
               topo_kw=FULL_TOPO if args.full else None,
               trace_queues=args.trace_queues, backend=args.backend,
               seeds=args.seeds)
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
