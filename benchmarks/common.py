"""Shared benchmark helpers: reduced-scale topologies + transport variants.

Every driver goes through the ONE experiment API
(``repro.sim.workloads.run``/``sweep``): a transport name from
``TRANSPORTS`` maps to a :class:`~repro.sim.workloads.RunConfig` via
``transport_config(tr, backend=...)``, so each figure is one
``run(scenario, cfg)`` call whichever backend/protocol/striping it needs.
"""
from __future__ import annotations

import time

from repro.core.params import NetworkSpec
from repro.sim.events import NetSim
from repro.sim.topology import FatTree
from repro.sim.workloads import RunConfig, run, sweep

# Reduced scale (container = 1 CPU core). Paper: 8192 hosts, <=100MB msgs.
QUICK_TOPO = dict(n_tor=4, hosts_per_tor=4)      # 16 hosts
FULL_TOPO = dict(n_tor=16, hosts_per_tor=16)     # 256 hosts
MSG_SIZES_QUICK = [4 * 2**10, 128 * 2**10, 512 * 2**10, 2 * 2**20]
MSG_SIZES_FULL = MSG_SIZES_QUICK + [8 * 2**20]

# Transport variant -> RunConfig fields.  ALL of these run on the jitted
# fabric now, including the 4-QP striped RoCEv2 ("roce4", previously the
# last event-backend benchmark leg).
TRANSPORT_CFG = {
    "strack": dict(protocol="strack", lb_mode="adaptive"),
    "strack-obl": dict(protocol="strack", lb_mode="oblivious"),
    "strack-fixed": dict(protocol="strack", lb_mode="fixed"),
    "roce": dict(protocol="rocev2"),
    "roce4": dict(protocol="rocev2", subflows=4),
}

TRANSPORTS = ["strack", "strack-obl", "roce", "roce4"]
FABRIC_TRANSPORTS = list(TRANSPORT_CFG)


def transport_config(transport: str, backend: str = "fabric",
                     **overrides) -> RunConfig:
    """RunConfig for one named transport variant on one backend."""
    if transport not in TRANSPORT_CFG:
        raise ValueError(f"unknown transport {transport!r}; expected one "
                         f"of {sorted(TRANSPORT_CFG)}")
    return RunConfig(backend=backend, **{**TRANSPORT_CFG[transport],
                                         **overrides})


def run_transport(transport: str, scenario, backend: str = "fabric",
                  **overrides) -> dict:
    """``run(scenario, cfg)`` for one named transport variant."""
    return run(scenario, transport_config(transport, backend, **overrides))


def sweep_transport(transport: str, scenarios, backend: str = "fabric",
                    **overrides) -> list:
    """``sweep(scenarios, cfg)`` for one named transport variant (fabric:
    one vmapped jit over the batch)."""
    return sweep(scenarios, transport_config(transport, backend,
                                             **overrides))


# Back-compat spellings (pre-RunConfig helpers).
def run_fabric_transport(transport: str, scenario, n_ticks=None,
                         trace_queues: bool = False) -> dict:
    return run_transport(transport, scenario, backend="fabric",
                         n_ticks=n_ticks, trace_queues=trace_queues)


def sweep_fabric_transport(transport: str, scenarios, n_ticks=None,
                           trace_queues: bool = False) -> list:
    return sweep_transport(transport, scenarios, backend="fabric",
                           n_ticks=n_ticks, trace_queues=trace_queues)


def run_events_transport(transport: str, scenario, until: float = 1e6,
                         seed: int = 0, log_queues: bool = False):
    """Run any TRANSPORTS variant on the event oracle; returns (result, sim)
    so callers can read queue-delay logs off the sim."""
    from repro.sim.workloads import run_scenario_on_sim
    sim = make_sim(transport, scenario.topo, scenario.net, seed=seed,
                   log_queues=log_queues)
    return run_scenario_on_sim(sim, scenario, until=until), sim


def make_sim(transport: str, topo: FatTree, net: NetworkSpec, **kw) -> NetSim:
    """Prebuilt NetSim for a named transport (queue-logging drivers)."""
    if transport == "strack":
        return NetSim(topo, net, transport="strack", **kw)
    if transport == "strack-obl":
        return NetSim(topo, net, transport="strack", oblivious_spray=True,
                      **kw)
    if transport == "roce":
        return NetSim(topo, net, transport="roce", **kw)
    if transport == "roce4":
        from repro.core.params import make_roce_params
        return NetSim(topo, net, transport="roce",
                      roce_params=make_roce_params(net, qps_per_conn=4),
                      **kw)
    raise ValueError(transport)


def timed(fn, *a, **kw):
    t0 = time.time()
    out = fn(*a, **kw)
    return out, time.time() - t0


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
