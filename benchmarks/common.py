"""Shared benchmark helpers: reduced-scale topologies + transport variants."""
from __future__ import annotations

import time

from repro.core.params import NetworkSpec
from repro.sim.events import NetSim
from repro.sim.topology import (FatTree, full_bisection, oversubscribed,
                                with_link_failures)

# Reduced scale (container = 1 CPU core). Paper: 8192 hosts, <=100MB msgs.
QUICK_TOPO = dict(n_tor=4, hosts_per_tor=4)      # 16 hosts
FULL_TOPO = dict(n_tor=16, hosts_per_tor=16)     # 256 hosts
MSG_SIZES_QUICK = [4 * 2**10, 128 * 2**10, 512 * 2**10, 2 * 2**20]
MSG_SIZES_FULL = MSG_SIZES_QUICK + [8 * 2**20]

TRANSPORTS = ["strack", "strack-obl", "roce", "roce4"]

# STrack spray variants that run on the jitted fabric fast path.
FABRIC_LB = {"strack": "adaptive", "strack-obl": "oblivious",
             "strack-fixed": "fixed"}
# Everything the fabric can run: the spray variants plus the ported RoCEv2
# (DCQCN + go-back-N + PFC) baseline.  Only the 4-QP striped variant still
# needs the event oracle.
FABRIC_TRANSPORTS = list(FABRIC_LB) + ["roce"]


def run_fabric_transport(transport: str, scenario, n_ticks=None,
                         trace_queues: bool = False) -> dict:
    """Run one transport variant on the jitted fabric backend."""
    from repro.sim.workloads import run_on_fabric
    if transport == "roce":
        return run_on_fabric(scenario, n_ticks=n_ticks, protocol="rocev2",
                             trace_queues=trace_queues)
    return run_on_fabric(scenario, n_ticks=n_ticks,
                         lb_mode=FABRIC_LB[transport],
                         trace_queues=trace_queues)


def sweep_fabric_transport(transport: str, scenarios, n_ticks=None,
                           trace_queues: bool = False) -> list:
    """Run one transport over a batch of same-shape scenarios (seed sweep)
    in a single vmapped jit; returns per-seed summaries."""
    from repro.sim.workloads import run_seed_sweep_on_fabric
    if transport == "roce":
        return run_seed_sweep_on_fabric(scenarios, n_ticks=n_ticks,
                                        protocol="rocev2",
                                        trace_queues=trace_queues)
    return run_seed_sweep_on_fabric(scenarios, n_ticks=n_ticks,
                                    lb_mode=FABRIC_LB[transport],
                                    trace_queues=trace_queues)


def run_events_transport(transport: str, scenario, until: float = 1e6,
                         seed: int = 0, log_queues: bool = False):
    """Run any TRANSPORTS variant on the event oracle; returns (result, sim)
    so callers can read queue-delay logs off the sim."""
    from repro.sim.workloads import run_scenario_on_sim
    sim = make_sim(transport, scenario.topo, scenario.net, seed=seed,
                   log_queues=log_queues)
    return run_scenario_on_sim(sim, scenario, until=until), sim


def make_sim(transport: str, topo: FatTree, net: NetworkSpec, **kw) -> NetSim:
    if transport == "strack":
        return NetSim(topo, net, transport="strack", **kw)
    if transport == "strack-obl":
        return NetSim(topo, net, transport="strack", oblivious_spray=True,
                      **kw)
    if transport == "roce":
        return NetSim(topo, net, transport="roce", **kw)
    if transport == "roce4":
        from repro.core.params import make_roce_params
        return NetSim(topo, net, transport="roce",
                      roce_params=make_roce_params(net, qps_per_conn=4),
                      **kw)
    raise ValueError(transport)


def timed(fn, *a, **kw):
    t0 = time.time()
    out = fn(*a, **kw)
    return out, time.time() - t0


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
