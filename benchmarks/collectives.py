"""Paper Figs. 1-2 & 21-28: collective workloads (Ring/DBT/HD AllReduce,
windowed AlltoAll), multi-job, full-bisection + 4:1 oversubscribed.

Validates: STrack > RoCEv2 (27.4% on AllReduce vs tuned 4-QP RoCEv2 in the
paper), and tighter finishing-time CDFs (fairness).

All three transports — STrack adaptive spray, RoCEv2 and the 4-QP striped
RoCEv2 — run the dependency-scheduled traces on the jitted fabric by
default (``run(collective_scenario(...), RunConfig(...))``); pass
``--backend events`` for the TraceRunner oracle.

    PYTHONPATH=src python -m benchmarks.collectives [--backend fabric]
    PYTHONPATH=src python -m benchmarks.collectives --smoke   # 2k-tick CI canary
"""
from __future__ import annotations

from repro.core.params import NetworkSpec
from repro.sim.topology import full_bisection, oversubscribed
from repro.sim.workloads import collective_scenario

from .common import run_transport, timed


def run_collectives(algo: str = "dbt", n_jobs: int = 4,
                    ranks_per_job: int = 8, collective_mb: float = 1.0,
                    oversub: int = 1, window: int = 8, seed: int = 0,
                    transports=("strack", "roce", "roce4"),
                    backend: str = "fabric", link_gbps: float = 400.0,
                    chunk: float = 128 * 1024, n_ticks=None):
    n_hosts_needed = n_jobs * ranks_per_job
    hp = 8
    n_tor = max(2, (n_hosts_needed + hp - 1) // hp)
    net = NetworkSpec(link_gbps=link_gbps)
    topo = (full_bisection(n_tor, hp) if oversub == 1
            else oversubscribed(n_tor, hp, oversub))
    kw = dict(window=window) if algo == "a2a" else {}
    sc = collective_scenario(topo, algo, n_jobs, ranks_per_job,
                             collective_mb * 2 ** 20, net=net, seed=seed,
                             chunk=chunk, **kw)
    rows = []
    fct = {}
    for tr in transports:
        res, wall = timed(run_transport, tr, sc, backend=backend,
                          n_ticks=n_ticks, until=1e7, seed=seed)
        times = list(res["group_fct"].values())
        fct[tr] = res["max_collective_time"]
        rows.append({
            "fig": "21-28", "workload": f"{algo}_x{n_jobs}_oversub{oversub}",
            "transport": tr, "backend": res["backend"],
            "max_collective_us": res["max_collective_time"],
            "min_collective_us": min(times) if times else None,
            "cdf_spread": ((max(times) - min(times)) / max(times)
                           if times else None),
            "finished": res["finished_groups"],
            "total": res["total_groups"],
            "drops": res["drops"], "pauses": res["pauses"],
            "wall_s": wall})
    if "roce" in fct and "strack" in fct:
        rows[-1]["speedup_vs_roce"] = fct["roce"] / fct["strack"]
    if "roce4" in fct and "strack" in fct:
        rows[-1]["speedup_vs_roce4"] = fct["roce4"] / fct["strack"]
    return rows


def run_motivation(seed: int = 0, backend: str = "fabric"):
    """Figs 1-2: single collective, DBT vs A2A, one job taking the
    cluster — RoCE single path vs STrack."""
    rows = []
    for algo in ("dbt", "a2a"):
        rows += run_collectives(algo, n_jobs=1, ranks_per_job=16,
                                collective_mb=4.0, seed=seed,
                                backend=backend)
    return rows


def run_smoke(n_ticks: int = 2000) -> list:
    """CI canary: a small ring collective must complete within ``n_ticks``
    on the jitted fabric for every transport (dependency gating + striping
    regressions fail fast here; chained via ``make smoke``)."""
    rows = run_collectives("ring", n_jobs=1, ranks_per_job=8,
                           collective_mb=0.125, link_gbps=100.0,
                           chunk=32 * 1024, n_ticks=n_ticks,
                           backend="fabric")
    for r in rows:
        assert r["backend"] == "fabric", r
        assert r["finished"] == r["total"], \
            f"collective canary unfinished: {r}"
        print(f"collective-smoke[{r['transport']}] ok: ring x8 on fabric in "
              f"{n_ticks} ticks | max_collective "
              f"{r['max_collective_us']:.1f}us drops {r['drops']} "
              f"({r['wall_s']:.1f}s wall)")
    return rows


def main():
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", choices=["fabric", "events"],
                    default="fabric")
    ap.add_argument("--smoke", action="store_true",
                    help="2k-tick collective-on-fabric CI canary")
    ap.add_argument("--n-ticks", type=int, default=None)
    args = ap.parse_args()
    if args.smoke:
        run_smoke(args.n_ticks or 2000)
        return
    rows = []
    for algo in ("ring", "dbt", "hd", "a2a"):
        rows += run_collectives(algo, backend=args.backend,
                                n_ticks=args.n_ticks)
        rows += run_collectives(algo, oversub=4, backend=args.backend,
                                n_ticks=args.n_ticks)
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
