"""Paper Figs. 1-2 & 21-28: collective workloads (Ring/DBT/HD AllReduce,
windowed AlltoAll), multi-job, full-bisection + 4:1 oversubscribed.

Validates: STrack > RoCEv2 (27.4% on AllReduce vs tuned 4-QP RoCEv2 in the
paper), and tighter finishing-time CDFs (fairness)."""
from __future__ import annotations

import statistics

from repro.core.params import NetworkSpec
from repro.sim.topology import full_bisection, oversubscribed
from repro.sim.workloads import TraceRunner
from repro.collective.algorithms import multi_job

from .common import make_sim, timed


def run_collectives(algo: str = "dbt", n_jobs: int = 4,
                    ranks_per_job: int = 8, collective_mb: float = 1.0,
                    oversub: int = 1, window: int = 8, seed: int = 0,
                    transports=("strack", "roce", "roce4")):
    n_hosts_needed = n_jobs * ranks_per_job
    hp = 8
    n_tor = max(2, (n_hosts_needed + hp - 1) // hp)
    rows = []
    fct = {}
    for tr in transports:
        net = NetworkSpec()
        topo = (full_bisection(n_tor, hp) if oversub == 1
                else oversubscribed(n_tor, hp, oversub))
        kw = dict(window=window) if algo == "a2a" else {}
        msgs, placement = multi_job(algo, n_jobs, ranks_per_job,
                                    topo.n_hosts,
                                    collective_mb * 2 ** 20, seed=seed,
                                    **kw)
        sim = make_sim(tr, topo, net, seed=seed)
        runner = TraceRunner(sim, msgs, placement)
        res, wall = timed(runner.run, until=1e7)
        times = list(res["group_fct"].values())
        fct[tr] = res["max_collective_time"]
        rows.append({
            "fig": "21-28", "workload": f"{algo}_x{n_jobs}_oversub{oversub}",
            "transport": tr,
            "max_collective_us": res["max_collective_time"],
            "min_collective_us": min(times) if times else None,
            "cdf_spread": ((max(times) - min(times)) / max(times)
                           if times else None),
            "finished": res["finished_groups"],
            "total": res["total_groups"],
            "drops": res["drops"], "pauses": res["pauses"],
            "wall_s": wall})
    if "roce" in fct and "strack" in fct:
        rows[-1]["speedup_vs_roce"] = fct["roce"] / fct["strack"]
    if "roce4" in fct and "strack" in fct:
        rows[-1]["speedup_vs_roce4"] = fct["roce4"] / fct["strack"]
    return rows


def run_motivation(seed: int = 0):
    """Figs 1-2: single collective, DBT vs A2A, one job taking the
    cluster — RoCE single path vs STrack."""
    rows = []
    for algo in ("dbt", "a2a"):
        rows += run_collectives(algo, n_jobs=1, ranks_per_job=16,
                                collective_mb=4.0, seed=seed)
    return rows


def main():
    rows = []
    for algo in ("ring", "dbt", "hd", "a2a"):
        rows += run_collectives(algo)
        rows += run_collectives(algo, oversub=4)
    for r in rows:
        print(r)


if __name__ == "__main__":
    main()
