"""RoCEv2 (DCQCN + go-back-N + PFC) on the jitted fabric vs the event
oracle, plus unit tests of the pieces the port introduced: the go-back-N
rewind, the DCQCN CNP rate cut, the in-order receiver, and the PFC
pause/resume hysteresis gate.

Parity runs pin ``roce_entropy_seed`` to the oracle's NetSim seed so both
backends assign identical per-flow QP entropies — the ECMP hash is
bit-exact, so the two simulators then contend on the *same* links and the
tick-quantisation tolerance bands stay meaningful.
"""
import numpy as np
import pytest

from repro.core.params import NetworkSpec, make_roce_params
from repro.sim.dcqcn_fab import (RoceMsg, init_roce_flow, init_roce_rcv,
                                 make_roce_fab_params, roce_done,
                                 roce_next_packet, roce_on_ack,
                                 roce_on_data, roce_on_timer)
from repro.sim.fabric import FabricConfig, pfc_gate, run_fabric, summarize
from repro.sim.topology import full_bisection
from repro.sim.workloads import (RunConfig, incast_scenario,
                                 permutation_scenario, run)

pytestmark = pytest.mark.tier1

NET = NetworkSpec(link_gbps=400.0)
TOPO44 = full_bisection(4, 4)        # 16 hosts, 4 ToRs, 4 spines
SEED = 1234                          # NetSim's default rng seed
BUF = 1e6                            # small shared buffer => PFC exercised

# The fabric is a tick-quantised approximation of the event oracle;
# completion times must agree within this factor, drops (where any)
# within 2x.  Tightened from (0.6, 1.6) by the per-hop latency pipeline
# (measured RoCEv2 ratios ~0.999-1.001: DCQCN pacing follows the same
# per-hop RTT on both backends).
FCT_TOL = (0.8, 1.25)


@pytest.fixture(scope="module")
def rp():
    return make_roce_fab_params(NET, make_roce_params(NET))


# --------------------------------------------------------------------------- #
# parity vs the oracle (acceptance: incast + permutation, lossless RoCEv2)
# --------------------------------------------------------------------------- #

def test_incast_roce_parity_vs_oracle():
    """8->1 incast, 512KB, lossless: FCTs agree, zero drops, PFC pauses
    fire on both backends."""
    sc = incast_scenario(TOPO44, 8, 512 * 2 ** 10, net=NET)
    ev = run(sc, RunConfig(backend="events", protocol="rocev2", until=2e6,
                           seed=SEED, switch_buffer_bytes=BUF))
    fb = run(sc, RunConfig(protocol="rocev2", switch_buffer_bytes=BUF,
                           roce_entropy_seed=SEED))
    assert ev["unfinished"] == 0 and fb["unfinished"] == 0
    r = fb["max_fct"] / ev["max_fct"]
    assert FCT_TOL[0] < r < FCT_TOL[1], (fb["max_fct"], ev["max_fct"])
    # lossless on both sides: PFC holds every packet
    assert ev["drops"] == 0 and fb["drops"] == 0
    assert ev["pauses"] > 0 and fb["pauses"] > 0, (ev["pauses"],
                                                   fb["pauses"])


def test_permutation_roce_parity_vs_oracle():
    """16-host permutation, 256KB: single-path DCQCN flows collide on the
    same ECMP uplinks on both backends; FCTs agree, nothing dropped."""
    sc = permutation_scenario(TOPO44, 256 * 2 ** 10, net=NET, seed=0)
    ev = run(sc, RunConfig(backend="events", protocol="rocev2", until=1e6,
                           seed=SEED, switch_buffer_bytes=2e6))
    fb = run(sc, RunConfig(protocol="rocev2", switch_buffer_bytes=2e6,
                           roce_entropy_seed=SEED))
    assert ev["unfinished"] == 0 and fb["unfinished"] == 0
    r = fb["max_fct"] / ev["max_fct"]
    assert FCT_TOL[0] < r < FCT_TOL[1], (fb["max_fct"], ev["max_fct"])
    assert ev["drops"] == 0 and fb["drops"] == 0


def test_summary_contract_reports_real_pauses():
    """summarize() carries the oracle's summary-dict contract, with real
    pause counts from the PFC model (not the old hardcoded 0)."""
    sc = incast_scenario(TOPO44, 8, 512 * 2 ** 10, net=NET)
    fb = run(sc, RunConfig(protocol="rocev2", switch_buffer_bytes=BUF))
    assert set(fb) >= {"max_fct", "avg_fct", "unfinished", "drops",
                       "pauses", "backend"}
    assert fb["pauses"] > 0
    # lossy STrack on the same scenario: no PFC, pauses must stay 0
    st = run(sc, RunConfig())
    assert st["pauses"] == 0


# --------------------------------------------------------------------------- #
# PFC: hysteresis gate unit test + pause/resume integration
# --------------------------------------------------------------------------- #

def test_pfc_gate_pause_resume_hysteresis():
    import jax.numpy as jnp
    xoff = jnp.asarray([100.0, 100.0, 100.0, 100.0])
    paused = jnp.asarray([False, True, True, False])
    ing = jnp.asarray([150.0,   70.0,  30.0,  70.0])
    out = np.asarray(pfc_gate(paused, ing, xoff, xon_frac=0.5))
    # above xoff -> pause; paused stays paused until below xon; unpaused
    # stays unpaused anywhere below xoff
    assert out.tolist() == [True, True, False, False]


def test_pfc_pauses_stop_drain_and_resume():
    """Integration: a deep lossless incast pauses ingress ports mid-run
    (queues stop draining, so nothing is dropped) and resumes them once
    the standing queue falls below the xon threshold."""
    sc = incast_scenario(TOPO44, 8, 512 * 2 ** 10, net=NET)
    cfg = FabricConfig(net=NET, protocol="rocev2",
                       switch_buffer_bytes=BUF)
    _, m = run_fabric(sc.topo, sc.flows, sc.default_ticks(), cfg)
    s = summarize(m)
    assert s["unfinished"] == 0 and s["drops"] == 0
    paused = np.asarray(m["paused_ports"])
    assert paused.max() > 0, "PFC never paused an ingress port"
    assert paused[-1] == 0, "pauses must clear once the incast drains"
    # while ports are paused the paused upstream queues stop draining:
    # pause events and zero drops together are only possible if the
    # backpressure actually held the excess in upstream buffers
    assert s["pauses"] > 0


def test_lossy_vs_lossless_rocev2():
    """pfc=False turns the same RoCEv2 run lossy: go-back-N now has to
    recover real drops, which PFC mode never sees."""
    sc = incast_scenario(TOPO44, 8, 512 * 2 ** 10, net=NET)
    lossless = run(sc, RunConfig(protocol="rocev2",
                                 switch_buffer_bytes=BUF))
    lossy = run(sc, RunConfig(protocol="rocev2", pfc=False,
                              n_ticks=30000))
    assert lossless["drops"] == 0 and lossless["unfinished"] == 0
    assert lossy["pauses"] == 0
    assert lossy["drops"] > 0, "8:1 incast into a 5-BDP tail-drop queue " \
                               "must shed packets without PFC"
    assert lossy["unfinished"] == 0, "go-back-N failed to recover drops"


# --------------------------------------------------------------------------- #
# go-back-N + DCQCN unit tests (pure transitions, no fabric)
# --------------------------------------------------------------------------- #

def _send_n(fs, p, n, now=0.0):
    psns = []
    for k in range(n):
        fs, (valid, psn, _, _) = roce_next_packet(fs, p, now + k * p.tick_us)
        assert bool(valid)
        psns.append(int(psn))
    return fs, psns


def test_goback_n_nack_retransmits_whole_tail(rp):
    """One gap NACK rewinds psn_next to the expected PSN: the entire tail
    after the loss is retransmitted, not just the missing packet."""
    fs = init_roce_flow(rp, total_pkts=10, entropy=7)
    fs, psns = _send_n(fs, rp, 6)
    assert psns == [0, 1, 2, 3, 4, 5]
    # receiver saw 0,1 then a gap (2 lost): NACK carries epsn=2
    nack = RoceMsg(valid=np.True_, ack=np.False_, nack=np.True_,
                   cnp=np.False_, epsn=np.int32(2),
                   bytes_recvd=np.float32(2 * rp.mtu_bytes))
    fs = roce_on_ack(fs, rp, nack, now=1.0)
    assert int(fs.psn_next) == 2, "go-back-N must rewind to the gap"
    assert int(fs.retransmits) == 4  # 2,3,4,5 all go again
    fs, psns = _send_n(fs, rp, 4, now=2.0)
    assert psns == [2, 3, 4, 5], "tail must be resent in order"


def test_rto_rewinds_to_snd_una(rp):
    fs = init_roce_flow(rp, total_pkts=8, entropy=3)
    fs, _ = _send_n(fs, rp, 8)
    ack = RoceMsg(valid=np.True_, ack=np.True_, nack=np.False_,
                  cnp=np.False_, epsn=np.int32(3),
                  bytes_recvd=np.float32(3 * rp.mtu_bytes))
    fs = roce_on_ack(fs, rp, ack, now=1.0)
    assert int(fs.snd_una) == 3
    # silence until RTO: everything from snd_una is resent
    fs, _ = roce_on_timer(fs, rp, now=1.0 + rp.rto_us + 1.0)
    assert int(fs.psn_next) == 3


def test_dcqcn_cnp_cuts_rate_and_recovers(rp):
    fs = init_roce_flow(rp, total_pkts=1000, entropy=0)
    line = rp.line_rate_Bpus
    assert float(fs.rate) == pytest.approx(line)
    cnp = RoceMsg(valid=np.True_, ack=np.False_, nack=np.False_,
                  cnp=np.True_, epsn=np.int32(0),
                  bytes_recvd=np.float32(0.0))
    fs = roce_on_ack(fs, rp, cnp, now=1.0)
    # alpha starts at 1.0: first CNP halves the rate, target remembers line
    assert float(fs.rate) == pytest.approx(line / 2)
    assert float(fs.target) == pytest.approx(line)
    # the ewma keeps alpha at 1.0 until the alpha timer decays it
    assert float(fs.alpha) == pytest.approx(1.0)
    # rate-increase timer: fast recovery climbs back toward target (and the
    # alpha timer decays alpha in the same sweep)
    r0 = float(fs.rate)
    fs, _ = roce_on_timer(fs, rp, now=1.0 + rp.dcqcn.rate_timer_us + 1.0)
    assert float(fs.alpha) < 1.0
    assert float(fs.rate) > r0
    assert float(fs.rate) == pytest.approx((r0 + line) / 2)


def test_roce_receiver_acks_nacks_cnps(rp):
    rcv = init_roce_rcv(total_pkts=4)
    mtu = float(rp.mtu_bytes)
    # in-order, below coalesce threshold: no message yet
    rcv, m = roce_on_data(rcv, rp, psn=0, size=mtu, ecn=False, now=0.0)
    assert not bool(m.valid)
    # second in-order packet hits ack_coalesce_pkts=2
    rcv, m = roce_on_data(rcv, rp, psn=1, size=mtu, ecn=False, now=0.1)
    assert bool(m.valid) and bool(m.ack) and int(m.epsn) == 2
    # gap: NACK with the expected psn, nothing delivered
    rcv, m = roce_on_data(rcv, rp, psn=3, size=mtu, ecn=False, now=0.2)
    assert bool(m.nack) and int(m.epsn) == 2
    assert float(rcv.bytes_recvd) == pytest.approx(2 * mtu)
    # ECN mark: CNP rides along, then is paced for cnp_interval_us
    rcv, m = roce_on_data(rcv, rp, psn=2, size=mtu, ecn=True, now=0.3)
    assert bool(m.cnp)
    rcv, m = roce_on_data(rcv, rp, psn=3, size=mtu, ecn=True, now=0.4)
    assert not bool(m.cnp), "CNPs must be paced per cnp_interval_us"
    assert int(rcv.epsn) == 4 and bool(m.ack), "final packet acks the tail"


def test_roce_done_and_window(rp):
    fs = init_roce_flow(rp, total_pkts=2, entropy=0)
    assert not bool(roce_done(fs))
    fs, _ = _send_n(fs, rp, 2)
    # window: nothing more to send until acked
    fs2, (valid, _, _, _) = roce_next_packet(fs, rp, now=5.0)
    assert not bool(valid)
    ack = RoceMsg(valid=np.True_, ack=np.True_, nack=np.False_,
                  cnp=np.False_, epsn=np.int32(2),
                  bytes_recvd=np.float32(2 * rp.mtu_bytes))
    fs = roce_on_ack(fs, rp, ack, now=5.0)
    assert bool(roce_done(fs))
