"""shard_map-partitioned fabric vs the single-device program (PR 6).

Every test asserts BIT-exactness: the sharded program keeps all
small-vector state replicated with identical op order and exchanges only
the popped ring heads + NIC offers across pods, so FCTs, drops, pauses
and warp trip counts must match the unsharded run exactly.

Runs under a forced multi-device host platform
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``, wired into
``make test-fast``); skips — loudly, via the ``shard`` marker — when the
runtime has fewer than 2 devices.
"""
import dataclasses

import jax
import pytest

from repro.sim import fabric as F
from repro.sim.topology import full_bisection
from repro.sim.workloads import Message, RunConfig, Scenario, run
from repro.core.params import NetworkSpec

pytestmark = [pytest.mark.tier1, pytest.mark.shard]

NDEV = jax.device_count()
needs_devices = pytest.mark.skipif(
    NDEV < 2, reason="needs >=2 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

TOPO = full_bisection(2, 4)
D = 4 if NDEV >= 4 else 2


def _pair(msgs, n_ticks, **kw):
    cfg = F.FabricConfig(trace_every=0, **kw)
    _, base = F.run_fabric_trace(TOPO, msgs, n_ticks, cfg)
    _, shrd = F.run_fabric_trace(TOPO, msgs, n_ticks,
                                 dataclasses.replace(cfg, shard=D))
    return base, shrd


def _assert_exact(base, shrd):
    assert base["fct_us"] == shrd["fct_us"]
    assert base["drops"] == shrd["drops"]
    assert base["pauses"] == shrd["pauses"]
    if "group_done_us" in base:
        assert base["group_done_us"] == shrd["group_done_us"]


@needs_devices
def test_shard_strack_permutation():
    msgs = [Message(mid=i, src=i, dst=(i + 3) % 8, size=65536.0,
                    deps=(), group=0) for i in range(8)]
    _assert_exact(*_pair(msgs, 6000))


@needs_devices
def test_shard_strack_padded_flow_axis():
    """6 flows over 4 pods: the flow axis pads to 8 with inert zero-packet
    flows; results must match the unpadded single-device run exactly
    (arbitration modulus uses the real flow count)."""
    msgs = [Message(mid=i, src=i, dst=(i + 3) % 8,
                    size=float(8192 + 4096 * i), deps=(), group=0)
            for i in range(6)]
    base, shrd = _pair(msgs, 6000)
    _assert_exact(base, shrd)
    assert len(shrd["fct_us"]) == 6     # pads sliced out of every metric


@needs_devices
def test_shard_roce_pfc_incast():
    msgs = [Message(mid=i, src=i, dst=7, size=150000.0, deps=(), group=0)
            for i in range(6)]
    base, shrd = _pair(msgs, 15000, protocol="rocev2", pfc=True)
    _assert_exact(base, shrd)


@needs_devices
def test_shard_lossy_roce_striped():
    msgs = [Message(mid=i, src=i, dst=(i + 5) % 8, size=100000.0,
                    deps=(), group=0) for i in range(6)]
    base, shrd = _pair(msgs, 12000, protocol="rocev2", pfc=False,
                       subflows=4)
    _assert_exact(base, shrd)


@needs_devices
def test_shard_chained_trace_warp():
    msgs = [Message(mid=i, src=i, dst=(i + 4) % 8, size=24576.0,
                    deps=(), group=0) for i in range(4)]
    msgs += [Message(mid=4 + i, src=(i + 4) % 8, dst=i, size=16384.0,
                     deps=(i,), group=1) for i in range(4)]
    base, shrd = _pair(msgs, 8000, time_warp=True)
    _assert_exact(base, shrd)
    assert base["warp_trips"] == shrd["warp_trips"]


@needs_devices
def test_shard_through_runconfig():
    """The workloads.run front door threads RunConfig.shard through."""
    net = NetworkSpec(link_gbps=400.0)
    msgs = tuple(Message(mid=i, src=i, dst=(i + 1) % 8, size=32768.0,
                         deps=(), group=0) for i in range(8))
    sc = Scenario("shard-front-door", TOPO, net, msgs)
    a = run(sc, RunConfig(backend="fabric"))
    b = run(sc, RunConfig(backend="fabric", shard=D))
    assert a["max_fct"] == b["max_fct"] and a["avg_fct"] == b["avg_fct"]


def test_shard_requires_devices_or_raises():
    """Asking for more pods than devices is a loud ValueError with the
    XLA_FLAGS recipe in the message, never a silent fallback."""
    msgs = [Message(mid=i, src=i, dst=(i + 1) % 8, size=8192.0,
                    deps=(), group=0) for i in range(8)]
    cfg = F.FabricConfig(trace_every=0, shard=2 * max(NDEV, 1))
    with pytest.raises(ValueError, match="device"):
        F.run_fabric_trace(TOPO, msgs, 2000, cfg)


def test_shard_rejects_trace_and_batch():
    msgs = [Message(mid=i, src=i, dst=(i + 1) % 8, size=8192.0,
                    deps=(), group=0) for i in range(8)]
    cfg = F.FabricConfig(trace_every=4, time_warp=False, shard=2)
    with pytest.raises(ValueError, match="trace"):
        F.run_fabric_trace(TOPO, msgs, 2000, cfg)
    with pytest.raises(ValueError, match="batch"):
        F.run_fabric_trace_batch(
            TOPO, [msgs, msgs], 2000,
            F.FabricConfig(trace_every=0, shard=2))
