"""Differential fuzz: random small Scenarios on the jitted fabric vs the
event oracle.

Every example draws a random topology (ToR/spine/host dims), message trace
(sizes including sub-MTU and odd non-MTU-multiple tails, optional
dependency edges and groups) and run config (protocol, lb_mode, subflows),
then asserts:

  * both backends finish every message,
  * fabric-vs-oracle completion time within the tightened per-hop parity
    band (ratio band with an absolute few-tick floor, since fuzz cases are
    RTT-scale where quantisation is relatively larger),
  * the event-horizon scan (``time_warp``) is BIT-exact vs dense ticking
    on the same scenario — FCT lists, drops and pauses.

Example count: ``REPRO_FUZZ_EXAMPLES`` (default 8; ``make test-fast`` runs
3, ``make test`` the default).  When ``hypothesis`` is installed an extra
property-based entry point drives the same checker from minimised draws;
the seeded loop below runs everywhere (the restricted container image has
no hypothesis).
"""
import os
import random

import jax
import pytest

from repro.core.params import NetworkSpec
from repro.sim.fabric import _rto_us
from repro.sim.faults import FaultSpec
from repro.sim.topology import full_bisection
from repro.sim.workloads import (Message, RunConfig, Scenario, _fabric_cfg,
                                 run)

pytestmark = [pytest.mark.tier1, pytest.mark.fuzz]

N_EXAMPLES = int(os.environ.get("REPRO_FUZZ_EXAMPLES", "8"))
#: The fault leg runs fewer examples: each compiles a faulted program on
#: fresh random dims, so it is the most compile-heavy entry point here.
N_FAULT_EXAMPLES = max(2, N_EXAMPLES // 2)
MTU = 4096

#: Ratio band for fabric/oracle completion-time parity.  Matches the
#: tightened deterministic gates (tests/test_fabric*.py, COLL_TOL) with
#: headroom for the tiny randomized scenarios this suite generates.
BAND = (0.7, 1.4)
#: Absolute floor: RTT-scale FCTs may differ by a few quantisation ticks
#: even when the relative band would flag them.
ABS_TICKS = 8.0


def random_scenario(rng: random.Random) -> Scenario:
    """Small random Scenario: dims, sizes (sub-MTU / exact / odd-tail),
    optional dependency chains and groups."""
    topo = full_bisection(rng.choice([2, 4]), rng.choice([2, 4]))
    net = NetworkSpec(link_gbps=rng.choice([100.0, 400.0]))
    n_msgs = rng.randint(3, 8)
    chained = rng.random() < 0.5
    msgs = []
    for i in range(n_msgs):
        src = rng.randrange(topo.n_hosts)
        dst = rng.randrange(topo.n_hosts)
        while dst == src:
            dst = rng.randrange(topo.n_hosts)
        shape = rng.randrange(3)
        if shape == 0:                       # sub-MTU message
            size = float(rng.randint(64, MTU - 1))
        elif shape == 1:                     # exact MTU multiple
            size = float(rng.randint(1, 12) * MTU)
        else:                                # odd tail packet
            size = float(rng.randint(1, 12) * MTU + rng.randint(1, MTU - 1))
        deps = ()
        if chained and i > 0 and rng.random() < 0.7:
            deps = tuple(sorted(rng.sample(range(i),
                                           rng.randint(1, min(2, i)))))
        group = 0 if chained else rng.randint(0, 1)
        msgs.append(Message(mid=i, src=src, dst=dst, size=size,
                            deps=deps, group=group))
    return Scenario("fuzz", topo, net, tuple(msgs))


def random_config(rng: random.Random, sc: Scenario) -> dict:
    """Random run-config axes both backends support."""
    if rng.random() < 0.5:
        return dict(protocol="strack", pfc=False,
                    lb_mode=rng.choice(["adaptive", "oblivious"]))
    kw = dict(protocol="rocev2", subflows=rng.choice([1, 4]))
    if not sc.has_deps:
        # deps-free traces launch in mid order on both backends, so the
        # oracle's QP entropy draw sequence can be replayed exactly;
        # dependency traces launch in completion order (band absorbs it)
        kw["roce_entropy_seed"] = 1234
    return kw


def check_parity(rng: random.Random) -> dict:
    """One fuzz example; returns diagnostics (used by the calibration
    script in docs/performance.md)."""
    sc = random_scenario(rng)
    kw = random_config(rng, sc)
    fb = run(sc, RunConfig(backend="fabric", **kw))
    fd = run(sc, RunConfig(backend="fabric", time_warp=False, **kw))
    ev = run(sc, RunConfig(backend="events", until=2e7, **kw))

    # --- time-warp bit-exactness on the randomized scenario ---
    assert fb["max_fct"] == fd["max_fct"], (kw, fb["max_fct"], fd["max_fct"])
    assert fb["avg_fct"] == fd["avg_fct"]
    assert fb["drops"] == fd["drops"] and fb["pauses"] == fd["pauses"]
    if "max_collective_time" in fb:
        assert fb["max_collective_time"] == fd["max_collective_time"]

    # --- active-set leg: capping the NIC lanes at n_flows-1 forces the
    # gather/scatter lane path and must stay bit-exact.  A RuntimeError
    # means the cap was genuinely exceeded (e.g. a deps-free trace where
    # every flow is released at t=0) — the loud-overflow contract, which
    # is itself the asserted behaviour in that case.
    nf = len(sc.messages) * kw.get("subflows", 1)
    if nf > 1:
        try:
            fa = run(sc, RunConfig(backend="fabric",
                                   active_cap=nf - 1, **kw))
        except RuntimeError as e:
            assert "active_cap" in str(e), e
        else:
            assert fa["max_fct"] == fb["max_fct"], (kw, fa, fb)
            assert fa["avg_fct"] == fb["avg_fct"]
            assert fa["drops"] == fb["drops"]
            assert fa["pauses"] == fb["pauses"]

    # --- kernel-backend leg: the same program with the hot stages run
    # through the interpret-mode Pallas kernels must be bit-exact (same
    # stage cores, different execution substrate) ---
    fk = run(sc, RunConfig(backend="fabric",
                           kernel_backend="pallas_interpret", **kw))
    assert fk["max_fct"] == fb["max_fct"], (kw, fk, fb)
    assert fk["avg_fct"] == fb["avg_fct"]
    assert fk["drops"] == fb["drops"] and fk["pauses"] == fb["pauses"]
    if "max_collective_time" in fb:
        assert fk["max_collective_time"] == fb["max_collective_time"]

    # --- sharded leg (auto-on when a device mesh is visible; `make
    # test-fast` forces a 4-device host platform for the shard-marked
    # entry point below) ---
    if jax.device_count() >= 2:
        fs = run(sc, RunConfig(backend="fabric", shard=2, **kw))
        assert fs["max_fct"] == fb["max_fct"], (kw, fs, fb)
        assert fs["avg_fct"] == fb["avg_fct"]
        assert fs["drops"] == fb["drops"] and fs["pauses"] == fb["pauses"]
        if "max_collective_time" in fb:
            assert fs["max_collective_time"] == fb["max_collective_time"]

    # --- both backends complete ---
    assert fb["unfinished"] == 0, (sc.messages, kw, fb)
    assert ev["unfinished"] == 0, (sc.messages, kw, ev)

    # --- completion-time parity in the tightened band ---
    if sc.is_trace:
        a, b = fb["max_collective_time"], ev["max_collective_time"]
    else:
        a, b = fb["max_fct"], ev["max_fct"]
    tick = sc.net.mtu_serialize_us
    ratio = a / b
    ok = (BAND[0] < ratio < BAND[1]) or abs(a - b) <= ABS_TICKS * tick
    assert ok, (sc.messages, kw, a, b, ratio)
    return dict(ratio=ratio, fabric_us=a, events_us=b, cfg=kw,
                n_msgs=len(sc.messages), has_deps=sc.has_deps)


@pytest.mark.parametrize("seed", range(N_EXAMPLES))
def test_fuzz_parity_seeded(seed):
    """Deterministic seeded sweep — runs on every image (no hypothesis)."""
    check_parity(random.Random(seed * 7919 + 13))


# --------------------------------------------------------------------------- #
# Fault leg: random seeded fault schedules through both backends
# --------------------------------------------------------------------------- #

def random_faults(rng: random.Random, topo) -> FaultSpec:
    """One random fault schedule: a both-direction flap, an uplink flap
    paired with a degraded sibling, or seeded corruption.  Windows start
    early (the tiny fuzz flows finish fast) and are bounded so the dense
    leg's horizon stays short."""
    S = topo.n_spine
    tor, spine = rng.randrange(topo.n_tor), rng.randrange(S)
    t0 = rng.randint(2, 12)
    t1 = t0 + rng.randint(20, 150)
    kind = rng.randrange(3)
    if kind == 0:
        return FaultSpec(link_flaps=((tor, spine, t0, t1),))
    if kind == 1:
        return FaultSpec(uplink_flaps=((tor, spine, t0, t1),),
                         link_degrade=((tor, (spine + 1) % S, t0, t1,
                                        rng.choice([0.25, 0.5, 0.75])),))
    return FaultSpec(link_corrupt=((tor, spine, t0, t1,
                                    rng.choice([0.02, 0.05, 0.1])),),
                     seed=rng.randrange(2 ** 20))


def check_fault_parity(rng: random.Random) -> dict:
    """One faulted fuzz example: drain on both backends, warp-vs-dense
    bit-exactness (recovery counters included), and fabric-vs-oracle
    completion inside a fault-aware band."""
    sc = random_scenario(rng)
    fs = random_faults(rng, sc.topo)
    kw = random_config(rng, sc)
    cfg = RunConfig(backend="fabric", faults=fs, **kw)
    fb = run(sc, cfg)
    fd = run(sc, RunConfig(backend="fabric", faults=fs, time_warp=False,
                           **kw))
    ev = run(sc, RunConfig(backend="events", faults=fs, until=2e7, **kw))

    # --- warp-vs-dense bit-exactness, chaos counters included ---
    for k in ("max_fct", "avg_fct", "drops", "pauses", "retransmits",
              "rto_fires", "sack_recoveries", "gbn_rewinds",
              "blackholed_pkts", "corrupt_drops"):
        assert fb[k] == fd[k], (kw, fs, k, fb[k], fd[k])

    # --- drain invariant: every faulted example recovers on BOTH backends
    assert fb["unfinished"] == 0, (sc.messages, kw, fs, fb)
    assert ev["unfinished"] == 0, (sc.messages, kw, fs, ev)

    # --- completion parity in a fault-aware band.  The backends model
    # degradation at different granularity (duty-cycled pops vs scaled
    # service times) and draw corruption at independently-reached
    # (tick, psn) keys, so the absolute slack covers the schedule span
    # plus — when a drop can land on one backend only — a few RTOs.
    a, b = fb["max_fct"], ev["max_fct"]
    tick = sc.net.mtu_serialize_us
    slack = fs.last_edge * tick + ABS_TICKS * tick
    if fs.link_corrupt or fs.host_corrupt:
        slack += 2.5 * _rto_us(_fabric_cfg(sc, cfg))
    ratio = a / b
    ok = (BAND[0] < ratio < BAND[1]) or abs(a - b) <= slack
    assert ok, (sc.messages, kw, fs, a, b, ratio, slack)
    return dict(ratio=ratio, fabric_us=a, events_us=b, cfg=kw,
                blackholed=fb["blackholed_pkts"],
                corrupt=fb["corrupt_drops"])


@pytest.mark.parametrize("seed", range(N_FAULT_EXAMPLES))
def test_fuzz_fault_parity_seeded(seed):
    """Seeded fault-schedule sweep (chaos leg of the fuzz surface)."""
    check_fault_parity(random.Random(seed * 6007 + 3))


@pytest.mark.shard
@pytest.mark.parametrize("seed", range(min(N_EXAMPLES, 4)))
def test_fuzz_parity_sharded(seed):
    """Same checker under a forced multi-device mesh so the shard=2 leg
    inside check_parity is guaranteed active (the `shard`-marked pytest
    pass runs with XLA_FLAGS=--xla_force_host_platform_device_count=4)."""
    if jax.device_count() < 2:
        pytest.skip("needs >=2 devices (force with XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")
    check_parity(random.Random(seed * 104729 + 7))


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=N_EXAMPLES, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.integers(min_value=0, max_value=2 ** 31 - 1))
    def test_fuzz_parity_hypothesis(seed):
        """Property-based wrapper over the same checker (minimising on the
        generator seed keeps draws reproducible across backends)."""
        check_parity(random.Random(seed))
