"""Program cache under the multi-tenant job axis (PR 6).

``run_fabric_trace_batch`` bucket-pads the vmapped job axis to the next
power of two, so nearby job counts present ONE input shape to the cached
program's ``jit_batch`` entry point: same compiled program
(``program_builds``) AND same jit trace (``program_traces`` — a python
side effect inside the program body that only fires while jax traces).
Also covers the LRU bound on the cache itself.
"""
import dataclasses

import pytest

from repro.sim import fabric as F
from repro.sim.topology import full_bisection
from repro.sim.workloads import Message

pytestmark = pytest.mark.tier1

TOPO = full_bisection(2, 4)
CFG = F.FabricConfig(trace_every=0)


def _perm_msgs(shift: int):
    """8-host permutation trace; ``shift`` varies the pattern (data, not
    structure) so batch entries differ while sharing one DepSpec."""
    return [Message(mid=i, src=i, dst=(i + shift) % 8, size=32768.0,
                    deps=(), group=0) for i in range(8)]


def test_job_bucket_rounding():
    assert [F._job_bucket(b) for b in (1, 2, 3, 4, 5, 7, 8, 9, 64, 65)] \
        == [1, 2, 4, 4, 8, 8, 8, 16, 64, 128]


def test_bucketed_job_counts_share_one_trace():
    """3 jobs and 4 jobs land in the same bucket (4): one program build,
    one jit trace, correct per-entry results for both calls."""
    F.clear_program_cache()
    batch3 = [_perm_msgs(s) for s in (1, 2, 3)]
    batch4 = [_perm_msgs(s) for s in (1, 2, 3, 5)]

    b0, t0 = F.program_builds, F.program_traces
    _, per3 = F.run_fabric_trace_batch(TOPO, batch3, 4000, CFG)
    builds_after_first = F.program_builds - b0
    traces_after_first = F.program_traces - t0
    assert builds_after_first == 1
    assert len(per3) == 3

    _, per4 = F.run_fabric_trace_batch(TOPO, batch4, 4000, CFG)
    assert F.program_builds - b0 == builds_after_first, \
        "same static shape must hit the program cache"
    assert F.program_traces - t0 == traces_after_first, \
        "job counts inside one bucket must reuse the jit trace"
    assert len(per4) == 4

    # pad entries replay entry 0 and are sliced off; the real entries
    # must agree with their unbatched runs
    _, solo = F.run_fabric_trace(TOPO, _perm_msgs(3), 4000, CFG)
    assert per3[2]["fct_us"] == per4[2]["fct_us"] == solo["fct_us"]


def test_bucket_boundary_retraces_once():
    """Crossing a bucket boundary (4 -> 5 jobs => bucket 8) is a new
    input shape: same cached program, exactly one extra jit trace."""
    F.clear_program_cache()
    b0 = F.program_builds
    F.run_fabric_trace_batch(TOPO, [_perm_msgs(s) for s in (1, 2, 3, 5)],
                             4000, CFG)
    t_mid = F.program_traces
    F.run_fabric_trace_batch(TOPO, [_perm_msgs(s) for s in (1, 2, 3, 5, 6)],
                             4000, CFG)
    assert F.program_builds - b0 == 1, "program cache key is shape-blind"
    assert F.program_traces - t_mid == 1


def test_lru_eviction(monkeypatch):
    """Touching more distinct shapes than _PROGRAM_CACHE_MAX evicts the
    oldest: re-running it rebuilds."""
    F.clear_program_cache()
    monkeypatch.setattr(F, "_PROGRAM_CACHE_MAX", 2)
    ticks = [3000, 3100, 3200]  # n_ticks is a static dim -> distinct keys
    for n in ticks:
        F.run_fabric_trace(TOPO, _perm_msgs(1), n, CFG)
    assert len(F._PROGRAM_CACHE) == 2
    before = F.program_builds
    F.run_fabric_trace(TOPO, _perm_msgs(1), ticks[-1], CFG)   # still cached
    assert F.program_builds == before
    F.run_fabric_trace(TOPO, _perm_msgs(1), ticks[0], CFG)    # evicted
    assert F.program_builds == before + 1
    F.clear_program_cache()


def test_n_real_is_part_of_cache_key():
    """Shard padding threads the real flow count into the program (NIC
    arbitration modulus); two runs differing only in n_real must not
    share a cached program."""
    k1 = F._program_key(TOPO, 8, 4000, CFG, F._trivial_dep(range(8)))
    assert k1 + (None,) != k1 + (6,)
