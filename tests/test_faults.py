"""Chaos fabric gates: time-varying fault injection + graceful degradation.

The fault subsystem (``sim/faults.py``, docs/robustness.md) turns link
flaps, degrades and seeded corruption into *fixed-shape program data*,
so one compiled program replays any schedule of the same shape.  This
suite pins the contracts the rest of the repo leans on:

* the corruption PRNG is replayable and backend-independent — the jnp
  draw, the host mirror and the raw ``traffic._u64`` stream agree bit
  for bit on every key;
* ``validate_faults`` rejects partitions, dead-link overlaps and
  malformed windows (and accepts the inert [0, 0) windows chaos soaks
  run clean epochs through);
* the degenerate t=0 uplink schedule is bit-exact against a natively
  dead-linked topology (same routing, same FCTs, zero blackholes);
* schedules of one shape share ONE compiled program (values are traced);
* ECMP/spray candidate masks are time-varying — a flapped uplink stops
  carrying traffic, and adaptive spray shifts entropy off a *degraded*
  uplink (ECN pressure) where oblivious spray cannot;
* every faulted scenario drains, losses show up in the recovery
  counters, and recovery lands within an RTO-derived bound;
* warp / dense / pallas-kernel / active-cap / shard executions stay
  bit-exact under a mixed fault schedule, recovery counters included;
* chaos soaks compile exactly one program and report per-tenant FCT
  degradation through the Prometheus registry.
"""
import numpy as np

import jax
import jax.numpy as jnp
import pytest

from repro.core.params import NetworkSpec
from repro.sim import fabric
from repro.sim.fabric import _rto_us
from repro.sim.faults import (NEVER, FaultSpec, fault_u01, fault_u01_py,
                              faults_from_dead_links, host_flap, link_corrupt,
                              link_degrade, link_flap, uplink_flap,
                              validate_faults)
from repro.sim.topology import full_bisection, with_link_failures
from repro.sim.workloads import (RunConfig, _fabric_cfg, permutation_scenario,
                                 run)

pytestmark = pytest.mark.tier1

NET = NetworkSpec(link_gbps=400.0)
TOPO = full_bisection(4, 4)
S = TOPO.n_spine
TICK = NET.mtu_serialize_us
PERM = permutation_scenario(TOPO, 128 * 2 ** 10, net=NET, seed=0)

#: Summary keys that must agree bit-for-bit across execution variants.
EXACT_KEYS = ("max_fct", "avg_fct", "unfinished", "drops", "pauses",
              "retransmits", "rto_fires", "sack_recoveries", "gbn_rewinds",
              "blackholed_pkts", "corrupt_drops", "ecn_marks")

#: The uniform recovery/chaos counter schema every summary must carry.
COUNTER_KEYS = ("retransmits", "rto_fires", "sack_recoveries",
                "gbn_rewinds", "blackholed_pkts", "corrupt_drops")

#: One entry of every fault class, all windows bounded (keeps the dense
#: scan horizon short) — the shape the bit-exactness legs share.
MIXED = FaultSpec(link_flaps=((0, 0, 10, 60),),
                  host_flaps=((5, 30, 80),),
                  link_degrade=((1, 1, 0, 200, 0.5),),
                  link_corrupt=((2, 2, 0, 300, 0.05),),
                  seed=3)


def _rto_of(sc, cfg: RunConfig) -> float:
    return _rto_us(_fabric_cfg(sc, cfg))


def _tor0_share(res) -> float:
    """Fraction of ToR 0's accepted uplink injections that rode spine 0."""
    tor0 = np.asarray(res["tx_rows_pkts"], dtype=float)[0:S]
    return float(tor0[0] / max(1.0, tor0.sum()))


# --------------------------------------------------------------------------- #
# PRNG: replayable, backend-independent
# --------------------------------------------------------------------------- #

def test_fault_prng_known_answer():
    """jnp draw == host mirror == the raw traffic._u64 stream, every key."""
    from repro.sim.traffic import _u64
    keys = [(0, 0, 0, 0), (1, 7, 123, 45), (2 ** 31 - 1, 95, 10 ** 6, 4095),
            (12345, 3, 999999, 1), (7, 0, 1, 0)]
    for (seed, row, tick, psn) in keys:
        dev = float(fault_u01(jnp.int32(seed), jnp.int32(row),
                              jnp.int32(tick), jnp.int32(psn)))
        host = fault_u01_py(seed, row, tick, psn)
        raw = float(_u64(seed, row, tick, psn) >> 40) / (1 << 24)
        assert dev == host == raw, (seed, row, tick, psn, dev, host, raw)
        assert 0.0 <= dev < 1.0


def test_fault_prng_vectorized_matches_host():
    """The in-scan vector draw equals elementwise host draws."""
    rows = jnp.arange(8, dtype=jnp.int32)
    psns = jnp.arange(8, dtype=jnp.int32) * 17 + 3
    dev = np.asarray(fault_u01(jnp.int32(42), rows, jnp.int32(77), psns))
    host = [fault_u01_py(42, int(r), 77, int(p))
            for r, p in zip(rows, psns)]
    np.testing.assert_array_equal(dev, np.asarray(host, dtype=np.float32))


# --------------------------------------------------------------------------- #
# Spec hygiene: shapes, horizons, validation
# --------------------------------------------------------------------------- #

def test_shape_key_counts_only():
    a = link_flap(0, 0, 10, 60)
    b = link_flap(3, 2, 500, 900, seed=77)
    assert a.shape_key == b.shape_key == (1, 0, 0, 0, 0, 0)
    assert MIXED.shape_key == (1, 0, 1, 1, 1, 0)
    assert MIXED.n_flap_windows == 2          # link + host flap windows


def test_last_edge_never_sentinel():
    """Permanent (NEVER-ended) windows count their start, so the default
    horizon extension stays finite for dead-link-style schedules."""
    assert FaultSpec().last_edge == 0
    assert link_flap(0, 0, 50, 400).last_edge == 400
    assert link_flap(0, 0, 50, NEVER).last_edge == 50
    dead = with_link_failures(TOPO, 2, 2, seed=0)
    assert faults_from_dead_links(dead).last_edge == 0


def test_validate_faults_rejects_malformed():
    with pytest.raises(ValueError, match="negative"):
        validate_faults(link_flap(0, 0, 5, 3), TOPO)
    with pytest.raises(ValueError, match="out of range"):
        validate_faults(link_flap(7, 0, 0, 10), TOPO)
    with pytest.raises(ValueError, match="out of range"):
        validate_faults(host_flap(99, 0, 10), TOPO)
    with pytest.raises(ValueError, match="credit"):
        validate_faults(FaultSpec(link_degrade=((0, 0, 0, 10, 0.0),)), TOPO)
    with pytest.raises(ValueError, match="prob"):
        validate_faults(link_corrupt(0, 0, 0, 10, 1.5), TOPO)
    # flapping a link that is already statically dead double-counts it
    dead = with_link_failures(TOPO, 1, 1, seed=0)
    (dt, ds) = sorted(dead.dead_links)[0]
    with pytest.raises(ValueError, match="dead_links"):
        validate_faults(uplink_flap(dt, ds, 0, 10), dead)
    # inert (empty) windows are legal: chaos soaks run clean epochs on them
    validate_faults(link_flap(0, 0, 0, 0), TOPO)


def test_validate_faults_rejects_partition():
    """No tick may leave a ToR with zero live uplinks."""
    cut = FaultSpec(link_flaps=tuple((0, s, 10, 50) for s in range(S)))
    with pytest.raises(ValueError, match="disconnect"):
        validate_faults(cut, TOPO)
    # staggered windows that never fully overlap are fine
    ok = FaultSpec(link_flaps=tuple((0, s, 10 + 50 * s, 40 + 50 * s)
                                    for s in range(S)))
    validate_faults(ok, TOPO)


# --------------------------------------------------------------------------- #
# t=0 schedule vs native dead links: bit-exact
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("protocol", ["strack", "rocev2"])
def test_dead_links_native_vs_chaos_bitexact(protocol):
    """``faults_from_dead_links`` on an alive topology reproduces the
    natively dead-linked run bit for bit: ECMP steers off the flapped
    uplinks from tick 0, so nothing is ever blackholed."""
    dead = with_link_failures(TOPO, 2, 2, seed=0)
    sc_nat = permutation_scenario(dead, 64 * 2 ** 10, net=NET, seed=0)
    sc_cha = permutation_scenario(TOPO, 64 * 2 ** 10, net=NET, seed=0)
    cha_cfg = RunConfig(backend="fabric", protocol=protocol,
                        faults=faults_from_dead_links(dead))
    nat = run(sc_nat, RunConfig(backend="fabric", protocol=protocol))
    cha = run(sc_cha, cha_cfg)
    for k in EXACT_KEYS:
        assert nat[k] == cha[k], (protocol, k, nat[k], cha[k])
    assert cha["blackholed_pkts"] == 0
    assert cha["unfinished"] == 0


# --------------------------------------------------------------------------- #
# One shape, one program
# --------------------------------------------------------------------------- #

def test_same_shape_schedules_share_one_program():
    """Fault values (windows, seeds) are traced data: re-running with a
    different schedule of the same shape must not rebuild the program."""
    cfg = dict(backend="fabric", protocol="strack", n_ticks=4000)
    run(PERM, RunConfig(faults=link_flap(0, 0, 10, 60), **cfg))     # warm
    builds = fabric.program_builds
    res = run(PERM, RunConfig(faults=link_flap(2, 3, 100, 250, seed=9),
                              **cfg))
    assert fabric.program_builds == builds, \
        "same-shape fault schedule retraced the fabric program"
    assert res["unfinished"] == 0


# --------------------------------------------------------------------------- #
# Entropy shifts: flapped uplinks leave the mask, degraded ones get
# avoided by adaptive spray only
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("lb_mode", ["adaptive", "oblivious"])
def test_flap_removes_uplink_from_spray_mask(lb_mode):
    """While (0,0) is down, both spray modes stop feeding it: only the
    packets injected before t0 (plus their retransmits) ever ride row 0,
    so its traffic share collapses vs the clean run."""
    base = RunConfig(backend="fabric", protocol="strack", lb_mode=lb_mode)
    clean = run(PERM, base)
    flap = run(PERM, RunConfig(backend="fabric", protocol="strack",
                               lb_mode=lb_mode,
                               faults=link_flap(0, 0, 10, NEVER)))
    assert flap["unfinished"] == 0
    assert flap["blackholed_pkts"] > 0      # in-flight pkts at t0 died
    assert _tor0_share(flap) < 0.5 * _tor0_share(clean), \
        (lb_mode, _tor0_share(clean), _tor0_share(flap))


def test_adaptive_shifts_entropy_off_degraded_uplink():
    """A degraded link stays in the ECMP mask (it still serves), so only
    ADAPTIVE spray can move traffic off it — the queue builds, ECN fires,
    and per-path weights steer away; oblivious spray keeps hashing onto
    it and pays the FCT."""
    sc = permutation_scenario(TOPO, 2 * 2 ** 20, net=NET, seed=0)
    deg = link_degrade(0, 0, 0, NEVER, 0.25)
    res = {}
    for lb in ("adaptive", "oblivious"):
        res[lb] = run(sc, RunConfig(backend="fabric", protocol="strack",
                                    lb_mode=lb, faults=deg, n_ticks=6000))
        assert res[lb]["unfinished"] == 0, lb
    ad, ob = _tor0_share(res["adaptive"]), _tor0_share(res["oblivious"])
    assert ad < ob - 0.02, (ad, ob)
    assert res["adaptive"]["max_fct"] < res["oblivious"]["max_fct"]


# --------------------------------------------------------------------------- #
# Loss recovery: drains, counters fire, bounded delay, attributed retx
# --------------------------------------------------------------------------- #

def test_flap_recovery_drains_within_rto_bound():
    """A mid-run flap must drain on every transport; losses appear in the
    recovery counters, retransmits are attributed to the flap window, and
    the completion slip is bounded by the outage plus a few RTOs."""
    flap = link_flap(0, 0, 50, 400)
    tot_bh = tot_recov = 0
    for kw in (dict(protocol="strack"),
               dict(protocol="strack", lb_mode="oblivious"),
               dict(protocol="rocev2"),
               dict(protocol="rocev2", subflows=4)):
        cfg = RunConfig(backend="fabric", faults=flap, **kw)
        clean = run(PERM, RunConfig(backend="fabric", **kw))
        res = run(PERM, cfg)
        tag = (kw["protocol"], kw.get("subflows", 1), kw.get("lb_mode"))
        assert res["unfinished"] == 0, (tag, res["max_fct"])
        bound = clean["max_fct"] + (400 - 50) * TICK \
            + 4 * _rto_of(PERM, cfg) + 8 * TICK
        assert res["max_fct"] <= bound, (tag, res["max_fct"], bound)
        recov = (res["rto_fires"] + res["sack_recoveries"]
                 + res["gbn_rewinds"])
        if res["blackholed_pkts"] > 0:
            # lost pkts must be re-sent, attributed to this flap window
            assert res["retransmits"] > 0, tag
            assert int(np.sum(res["win_retx"])) > 0, tag
        tot_bh += res["blackholed_pkts"]
        tot_recov += recov
    # loss/recovery is gated in aggregate: ECMP leaves the flapped uplink
    # the tick it goes down, so a single-path transport may legitimately
    # lose only what was already queued on it (possibly nothing)
    assert tot_bh > 0, "flap overlapped live flows but nothing was lost"
    assert tot_recov > 0, "packets were lost but no recovery path fired"


def test_corruption_replayable_and_recovered():
    """Same (schedule, seed) => bit-identical run; the seed is program
    data (same shape), and corrupt drops are recovered, not stranded."""
    cor = link_corrupt(0, 0, 0, NEVER, 0.2, seed=7)
    a = run(PERM, RunConfig(backend="fabric", faults=cor))
    b = run(PERM, RunConfig(backend="fabric", faults=cor))
    for k in EXACT_KEYS:
        assert a[k] == b[k], (k, a[k], b[k])
    assert a["corrupt_drops"] > 0
    assert a["unfinished"] == 0
    assert a["retransmits"] > 0
    # a different seed rides through the SAME program (values only)
    builds = fabric.program_builds
    c = run(PERM, RunConfig(backend="fabric",
                            faults=link_corrupt(0, 0, 0, NEVER, 0.2,
                                                seed=8)))
    assert fabric.program_builds == builds
    assert c["unfinished"] == 0


# --------------------------------------------------------------------------- #
# Bit-exactness across execution variants under a mixed schedule
# --------------------------------------------------------------------------- #

def _mixed_runs(kw, legs):
    base = run(PERM, RunConfig(backend="fabric", faults=MIXED,
                               n_ticks=6000, **kw))
    assert base["unfinished"] == 0
    assert base["blackholed_pkts"] > 0 or base["corrupt_drops"] > 0
    for tag, okw in legs:
        r = run(PERM, RunConfig(backend="fabric", faults=MIXED,
                                n_ticks=6000, **kw, **okw))
        for k in EXACT_KEYS:
            assert r[k] == base[k], (kw, tag, k, r[k], base[k])
        np.testing.assert_array_equal(
            np.asarray(r["tx_rows_pkts"]),
            np.asarray(base["tx_rows_pkts"]), err_msg=str((kw, tag)))
        np.testing.assert_array_equal(
            np.asarray(r["win_retx"]),
            np.asarray(base["win_retx"]), err_msg=str((kw, tag)))
    return base


def test_mixed_faults_bitexact_strack():
    """Warp, dense, pallas-interpret kernels and the capped active set
    must replay the identical faulted run (counters included)."""
    _mixed_runs(dict(protocol="strack"),
                [("dense", dict(time_warp=False)),
                 ("pallas", dict(kernel_backend="pallas_interpret")),
                 ("cap", dict(active_cap=len(PERM.messages)))])


def test_mixed_faults_bitexact_roce():
    """Same invariant on the go-back-N/RTO recovery path."""
    _mixed_runs(dict(protocol="rocev2", subflows=4),
                [("dense", dict(time_warp=False))])


@pytest.mark.shard
def test_mixed_faults_bitexact_sharded():
    """shard=2 under the mixed schedule (forced multi-device pass)."""
    if jax.device_count() < 2:
        pytest.skip("needs >=2 devices (force with XLA_FLAGS="
                    "--xla_force_host_platform_device_count=4)")
    _mixed_runs(dict(protocol="strack"), [("shard", dict(shard=2))])


# --------------------------------------------------------------------------- #
# Events oracle honours the same spec
# --------------------------------------------------------------------------- #

def test_events_backend_honours_faultspec():
    """The oracle blackholes on the same flap windows and drains through
    the same recovery machinery (band parity lives in test_fuzz_parity)."""
    res = run(PERM, RunConfig(backend="events", until=2e7,
                              faults=link_flap(0, 0, 50, 400)))
    assert res["unfinished"] == 0
    assert res["blackholed_pkts"] > 0
    for k in COUNTER_KEYS:
        assert isinstance(res[k], int), k


def test_uniform_recovery_schema():
    """Clean runs on every backend/protocol still carry the full
    recovery/chaos counter schema, zero-filled — dashboards and the
    bench gate must never KeyError (fix satellite)."""
    for cfg in (RunConfig(backend="fabric", protocol="strack"),
                RunConfig(backend="fabric", protocol="rocev2"),
                RunConfig(backend="events", until=2e7)):
        res = run(PERM, cfg)
        for k in COUNTER_KEYS:
            assert isinstance(res[k], int) and res[k] >= 0, (cfg.backend, k)
        assert res["blackholed_pkts"] == 0
        assert res["corrupt_drops"] == 0


# --------------------------------------------------------------------------- #
# Chaos soak: one program, degradation reported
# --------------------------------------------------------------------------- #

def test_chaos_soak_one_program_and_degradation():
    from repro.obs.metrics import MetricsRegistry, render_prometheus
    from repro.sim.traffic import InferenceTenant, TrainingJob, soak
    reg = MetricsRegistry()
    res = soak(TOPO,
               [TrainingJob(name="train0", algo="ring", ranks=8,
                            collective_bytes=64 * 2 ** 10, steps=2)],
               [InferenceTenant(name="infer0", n_flows=16)],
               epochs=3, seed=0, registry=reg,
               chaos=[None, link_flap(0, 0, 10, 120), None])
    assert res["program_builds"] <= 1, res["program_builds"]
    assert res["totals"]["unfinished"] == 0
    assert [row["chaos"] for row in res["epoch_rows"]] == \
        [False, True, False]
    for name, agg in res["per_tenant"].items():
        d = agg["degradation_p99"]
        assert d == d and d > 0, (name, d)     # computed, not NaN
    prom = render_prometheus(reg)
    assert "strack_fct_degradation_ratio" in prom
    assert "strack_blackholed_pkts_total" in prom


def test_chaos_soak_rejects_shape_mismatch():
    from repro.sim.traffic import InferenceTenant, soak
    with pytest.raises(ValueError, match="shape_key"):
        soak(TOPO, [], [InferenceTenant(name="t", n_flows=4)], epochs=2,
             chaos=[link_flap(0, 0, 1, 5), host_flap(0, 1, 5)])
    with pytest.raises(ValueError, match="all-None"):
        soak(TOPO, [], [InferenceTenant(name="t", n_flows=4)], epochs=2,
             chaos=[None, None])
