"""End-to-end JAX flow engine over a scripted lossy channel.

Drives flow_next_packet / receiver_on_data / flow_on_sack in a discrete
loop with a fixed-latency channel and deterministic drops; the message must
complete (selective retransmission + OOO/probe/RTO detection all running in
fixed-shape JAX)."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    NetworkSpec, make_strack_params, init_flow, flow_on_sack,
    flow_next_packet, flow_on_timer, flow_done, init_receiver,
    receiver_on_data,
)

NET = NetworkSpec(link_gbps=400.0)


def run_flow(total_pkts, drop_set, *, max_paths=32, tick_us=0.5,
             one_way_us=4.0, max_ticks=40000, drop_once=True):
    """Simulate one flow over a fixed-delay pipe; drop psn on its Nth tx."""
    p = make_strack_params(NET, max_paths=max_paths)
    jit_tx = jax.jit(flow_next_packet, static_argnums=1)
    jit_rx = jax.jit(receiver_on_data, static_argnums=1)
    jit_ack = jax.jit(flow_on_sack, static_argnums=1)
    jit_timer = jax.jit(flow_on_timer, static_argnums=1)

    fs = init_flow(p, total_pkts)
    rs = init_receiver(total_pkts)
    pipe = []   # (deliver_tick, kind, fields)
    seen_tx = {}
    now = 0.0
    for tick in range(max_ticks):
        now = tick * tick_us
        # deliveries
        due = [x for x in pipe if x[0] <= tick]
        pipe = [x for x in pipe if x[0] > tick]
        for _, kind, fields in due:
            if kind == "data":
                psn, entropy, ts, probe = fields
                rs, sack = jit_rx(rs, p, jnp.int32(psn),
                                  jnp.float32(p.mtu_bytes),
                                  jnp.asarray(False), jnp.int32(entropy),
                                  jnp.float32(ts), jnp.asarray(probe))
                if bool(sack.valid):
                    pipe.append((tick + int(one_way_us / tick_us), "sack",
                                 sack))
            else:
                fs = jit_ack(fs, p, fields, jnp.float32(now))
        if flow_done(fs):
            return True, tick, fs, rs
        # timers
        fs, probe_tx = jit_timer(fs, p, jnp.float32(now))
        if bool(probe_tx.valid):
            pipe.append((tick + int(one_way_us / tick_us), "data",
                         (int(probe_tx.psn), int(probe_tx.entropy), now,
                          True)))
        # transmissions (up to 2 per tick, window permitting)
        for _ in range(2):
            fs, tx = jit_tx(fs, p, jnp.float32(now))
            if not bool(tx.valid):
                break
            psn = int(tx.psn)
            seen_tx[psn] = seen_tx.get(psn, 0) + 1
            if psn in drop_set and (seen_tx[psn] == 1 or not drop_once):
                continue  # dropped on first transmission
            pipe.append((tick + int(one_way_us / tick_us), "data",
                         (psn, int(tx.entropy), now, False)))
    return False, max_ticks, fs, rs


def test_lossless_completes():
    ok, ticks, fs, rs = run_flow(64, drop_set=set())
    assert ok
    assert int(rs.epsn) == 64
    assert float(rs.bytes_recvd) == 64 * 4096.0


def test_single_loss_recovers():
    ok, ticks, fs, rs = run_flow(64, drop_set={13})
    assert ok
    assert int(rs.epsn) == 64


def test_burst_loss_recovers():
    ok, ticks, fs, rs = run_flow(96, drop_set=set(range(20, 40)))
    assert ok


def test_tail_loss_probe_recovers():
    """Losing the final packets leaves no OOO signal: probe/RTO must fire."""
    ok, ticks, fs, rs = run_flow(32, drop_set={30, 31})
    assert ok


def test_first_window_loss_recovers():
    ok, ticks, fs, rs = run_flow(48, drop_set={0, 1, 2, 3})
    assert ok


@settings(max_examples=15, deadline=None)
@given(st.sets(st.integers(0, 79), max_size=25))
def test_random_losses_always_complete(drops):
    ok, ticks, fs, rs = run_flow(80, drop_set=drops)
    assert ok, f"stuck with drops={sorted(drops)}"
    assert float(rs.bytes_recvd) == 80 * 4096.0


def test_inflight_never_negative():
    from repro.core.reliability import inflight_bytes
    ok, ticks, fs, rs = run_flow(64, drop_set={5, 6, 7})
    assert ok
    assert float(inflight_bytes(fs.rel)) >= -1e-3
