"""Event-horizon (time-warp) scan vs dense ticking, the jitted-program
cache, decimated tracing, and multi-axis sweep() — the gates for the perf
refactor.

The time-warp contract is *exact* parity, not a tolerance band: a skipped
tick must be a provable no-op, so completion ticks, FCTs, drop and pause
counts are bit-identical to dense ticking on every scenario class the
fabric supports (STrack spray permutation, lossless RoCEv2 incast with
real PFC pauses, and a dependency-chained collective ring reusing the
test_collective_fabric fixture shape).
"""
import numpy as np
import pytest

from repro.core.params import NetworkSpec
from repro.sim import fabric
from repro.sim.fabric import FabricConfig, run_fabric_trace, summarize
from repro.sim.topology import full_bisection
from repro.sim.workloads import (RunConfig, collective_scenario,
                                 incast_scenario, permutation_scenario,
                                 run, sweep)

pytestmark = pytest.mark.tier1

NET = NetworkSpec(link_gbps=400.0)
NET100 = NetworkSpec(link_gbps=100.0)
TOPO44 = full_bisection(4, 4)        # 16 hosts
TOPO24 = full_bisection(2, 4)        # 8 hosts (collective band fixture)


def _both(sc, n_ticks=None, **cfg_kw):
    """(dense_metrics, warp_metrics) for one scenario, same program cfg."""
    ticks = n_ticks or sc.default_ticks()
    out = []
    for warp in (False, True):
        cfg = FabricConfig(net=sc.net, time_warp=warp, trace_every=0,
                           **cfg_kw)
        _, m = run_fabric_trace(sc.topo, sc.messages, ticks, cfg)
        out.append(m)
    return out


def _assert_exact(md, mw):
    np.testing.assert_array_equal(md["done_tick"], mw["done_tick"])
    assert md["fct_us"] == mw["fct_us"]
    assert md["subflow_fct_us"] == mw["subflow_fct_us"]
    assert md["msg_release_us"] == mw["msg_release_us"]
    assert md["drops"] == mw["drops"]
    assert md["pauses"] == mw["pauses"]
    if "group_done_us" in md:
        assert md["group_done_us"] == mw["group_done_us"]


# --------------------------------------------------------------------------- #
# exact dense-vs-warp parity across the scenario matrix
# --------------------------------------------------------------------------- #

def test_timewarp_parity_strack_permutation():
    """STrack adaptive spray, 16-host permutation: completion ticks,
    FCTs and drops are preserved exactly by the event-horizon scan."""
    sc = permutation_scenario(TOPO44, 256 * 2 ** 10, net=NET, seed=0)
    md, mw = _both(sc)
    _assert_exact(md, mw)
    assert all(f is not None for f in mw["fct_us"])


def test_timewarp_parity_roce_incast_pfc():
    """Lossless RoCEv2 8->1 incast: PFC pause counts (and the pacing /
    DCQCN-timer wakeups warp must honour) are preserved exactly."""
    sc = incast_scenario(TOPO44, 8, 512 * 2 ** 10, net=NET)
    md, mw = _both(sc, protocol="rocev2", switch_buffer_bytes=1e6,
                   roce_entropy_seed=1234)
    _assert_exact(md, mw)
    assert mw["pauses"] > 0 and mw["drops"] == 0


def test_timewarp_parity_chained_ring():
    """Dependency-chained ring allreduce (the test_collective_fabric band
    fixture shape): release ticks and group completions are preserved —
    and the scan actually skips (trips << n_ticks), since dep stalls and
    SACK-pipe round trips dominate a chained trace."""
    sc = collective_scenario(TOPO24, "ring", 1, 8, 512 * 2 ** 10,
                             net=NET100, seed=0, chunk=32 * 2 ** 10)
    assert sc.has_deps
    ticks = sc.default_ticks()
    md, mw = _both(sc, n_ticks=ticks)
    _assert_exact(md, mw)
    trips = int(np.asarray(mw["warp_trips"]))
    assert trips < ticks // 3, (trips, ticks)


@pytest.mark.slow
def test_timewarp_parity_lossy_roce_rto_gaps():
    """Lossy RoCEv2 incast: go-back-N RTO recovery leaves long dead
    intervals; warp must wake exactly at the timer sweeps dense fires."""
    sc = incast_scenario(TOPO44, 8, 512 * 2 ** 10, net=NET)
    md, mw = _both(sc, n_ticks=30000, protocol="rocev2", pfc=False)
    _assert_exact(md, mw)
    assert md["drops"] > 0


# --------------------------------------------------------------------------- #
# program cache: same-shape runs compile exactly once
# --------------------------------------------------------------------------- #

def test_program_cache_single_build_across_runs():
    """Two same-shape scenarios (different seeds AND different lb_mode /
    entropy seed) must build the fabric program exactly once — the
    trace-count hook on _make_program is the regression gate."""
    sc0 = permutation_scenario(TOPO44, 64 * 2 ** 10, net=NET, seed=11)
    sc1 = permutation_scenario(TOPO44, 64 * 2 ** 10, net=NET, seed=12)
    cfg = RunConfig(n_ticks=4000)
    run(sc0, cfg)  # may or may not hit a previous test's program
    before = fabric.program_builds
    run(sc1, cfg)
    run(sc0, RunConfig(n_ticks=4000, lb_mode="oblivious"))
    run(sc1, RunConfig(n_ticks=4000, lb_mode="fixed"))
    assert fabric.program_builds == before, \
        "same-shape run() re-traced the fabric program"
    # a different static shape DOES build (the cache keys on dims)
    run(sc0, RunConfig(n_ticks=4001))
    assert fabric.program_builds == before + 1


def test_program_cache_spans_run_and_sweep():
    """sweep() over seeds and config axes reuses one cached program, and
    the batch of (lb_mode x entropy-seed) axes returns per-axis rows."""
    scs = [permutation_scenario(TOPO44, 64 * 2 ** 10, net=NET, seed=s)
           for s in range(3)]
    cfg = RunConfig(n_ticks=4000)
    sweep(scs, cfg)
    before = fabric.program_builds
    rows = sweep([scs[0]],
                 [RunConfig(n_ticks=4000, lb_mode=m)
                  for m in ("adaptive", "oblivious", "fixed")])
    assert fabric.program_builds == before
    assert [r["lb_mode"] for r in rows] == ["adaptive", "oblivious",
                                            "fixed"]
    # fixed single-path pinning must differ from adaptive spray on a
    # loaded permutation — proof the traced lb_code axis actually steers
    assert rows[2]["max_fct"] != rows[0]["max_fct"]


def test_sweep_mixed_static_axes_partition():
    """Axes that change the program (subflows) partition into groups but
    still come back in input order with their config identity."""
    sc = permutation_scenario(TOPO44, 64 * 2 ** 10, net=NET, seed=3)
    rows = sweep([sc], [RunConfig(protocol="rocev2", subflows=k,
                                  n_ticks=6000) for k in (1, 4)])
    assert [r["subflows"] for r in rows] == [1, 4]
    assert all(r["unfinished"] == 0 for r in rows)


def test_sweep_length_mismatch_rejected():
    sc = permutation_scenario(TOPO44, 64 * 2 ** 10, net=NET, seed=0)
    with pytest.raises(ValueError, match="lengths must match"):
        sweep([sc, sc, sc], [RunConfig(), RunConfig()])


# --------------------------------------------------------------------------- #
# trace decimation + events-only summaries stay exact
# --------------------------------------------------------------------------- #

def test_trace_decimation_keeps_summary_exact():
    """trace_every=k decimates the stacked trace k-fold but summaries come
    from the final scan carry, so they are bit-equal to dense tracing —
    including a tick horizon that is not a multiple of k."""
    sc = permutation_scenario(TOPO44, 256 * 2 ** 10, net=NET, seed=1)
    ticks = 5001
    _, m1 = run_fabric_trace(sc.topo, sc.messages, ticks,
                             FabricConfig(net=NET, trace_every=1))
    _, m5 = run_fabric_trace(sc.topo, sc.messages, ticks,
                             FabricConfig(net=NET, trace_every=5))
    assert np.asarray(m1["qsize"]).shape[0] == ticks
    assert np.asarray(m5["qsize"]).shape[0] == ticks // 5
    assert summarize(m1) == summarize(m5)
    assert m1["fct_us"] == m5["fct_us"]


def test_no_trace_mode_omits_arrays_but_summarizes():
    sc = permutation_scenario(TOPO44, 64 * 2 ** 10, net=NET, seed=2)
    _, m = run_fabric_trace(sc.topo, sc.messages, 4000,
                            FabricConfig(net=NET, trace_every=0))
    assert "qsize" not in m
    s = summarize(m)
    assert s["unfinished"] == 0 and s["drops"] == 0
    # exact finals ride along for downstream consumers
    assert m["delivered_final"].shape == (len(sc.messages),)


def test_run_config_trace_knob_validation():
    with pytest.raises(ValueError, match="trace_every"):
        RunConfig(trace_every=-1)


def test_run_default_is_warp_and_reports_diagnostics():
    """run() defaults to the event-horizon scan and surfaces its trip
    diagnostics; trace_queues AND an explicit trace_every both force
    dense ticking (a data-dependent trip count cannot stack a trace)."""
    sc = permutation_scenario(TOPO44, 64 * 2 ** 10, net=NET, seed=4)
    res = run(sc, RunConfig(n_ticks=4000))
    assert res["warp_trips"] < 4000
    dense = run(sc, RunConfig(n_ticks=4000, trace_queues=True))
    assert "warp_trips" not in dense
    assert dense["queue_settle_us"] >= 0.0
    assert dense["max_fct"] == res["max_fct"]
    decimated = run(sc, RunConfig(n_ticks=4000, trace_every=8))
    assert "warp_trips" not in decimated  # trace_every=8 implies dense
    assert decimated["max_fct"] == res["max_fct"]


def test_queue_settle_decimation_scales_rows_not_threshold():
    """Decimating the trace must not inflate the queue-delay threshold
    comparison: settle times agree between k=1 and k=4 up to the k-tick
    row quantisation."""
    sc = incast_scenario(TOPO44, 8, 512 * 2 ** 10, net=NET)
    dense = run(sc, RunConfig(n_ticks=12000, trace_queues=True))
    deci = run(sc, RunConfig(n_ticks=12000, trace_queues=True,
                             trace_every=4))
    tick = NET.mtu_serialize_us
    assert dense["queue_settle_us"] > 0
    assert abs(deci["queue_settle_us"] - dense["queue_settle_us"]) \
        <= 4 * tick


def test_sweep_events_backend_allows_heterogeneous_scenarios():
    """The shared-structure rule exists for the vmapped fabric batch; an
    events-backend sweep simply loops the oracle and accepts any mix."""
    small = permutation_scenario(TOPO24, 32 * 2 ** 10, net=NET, seed=0)
    other = permutation_scenario(TOPO44, 32 * 2 ** 10, net=NET, seed=0)
    rows = sweep([small, other], RunConfig(backend="events", until=1e6))
    assert [r["backend"] for r in rows] == ["events", "events"]
    assert all(r["unfinished"] == 0 for r in rows)
