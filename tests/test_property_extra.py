"""Extra property tests: kernel shape sweeps via hypothesis and transport
invariants under randomized ACK orderings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import NetworkSpec, make_strack_params
from repro.core import ref
from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention as fa_raw

NET = NetworkSpec()


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 2),
    kv=st.sampled_from([1, 2, 4]),
    grp=st.sampled_from([1, 2, 4]),
    tq=st.sampled_from([32, 64, 100]),
    tk=st.sampled_from([64, 128, 160]),
    hd=st.sampled_from([32, 64]),
    causal=st.booleans(),
)
def test_flash_attention_random_shapes(b, kv, grp, tq, tk, hd, causal):
    H = kv * grp
    ks = jax.random.split(jax.random.PRNGKey(tq * tk + hd), 3)
    q = jax.random.normal(ks[0], (b, H, tq, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, kv, tk, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, kv, tk, hd), jnp.float32)
    got = fa_raw(q, k, v, causal=causal, block_q=64, block_k=64)
    want = kref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.floats(0.0, 60.0)),
                min_size=1, max_size=200))
def test_cwnd_always_within_bounds(acks):
    """CC invariant: cwnd stays in [min_cwnd, max_cwnd] for ANY ack trace."""
    p = make_strack_params(NET)
    cc = ref.CCState(p)
    now = 0.0
    for ecn, delay in acks:
        now += 0.7
        cc.update_achieved_bdp(4096.0, False, now)
        cc.adjust_cwnd(ecn, delay, cc.achieved_bdp_pkts, now)
        assert p.min_cwnd_pkts <= cc.cwnd <= p.max_cwnd_pkts


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(0, 15)),
                min_size=1, max_size=100))
def test_spray_never_returns_marked_path(updates):
    """LB invariant: a freshly ECN-marked entropy is not chosen next unless
    everything is marked (in which case one bit is cleared first)."""
    p = make_strack_params(NET, max_paths=16)
    s = ref.SprayState(p)
    for ecn, path in updates:
        s.update_ecn_bitmap(ecn, path)
    before = list(s.bitmap)
    got = s.choose_path(8.0, now=0.0)
    if not all(before[:16]):
        assert s.bitmap[got] == 0
