"""Property tests: the JAX STrack core must match the pure-Python oracle."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    NetworkSpec, make_strack_params,
    init_cc, adjust_cwnd, update_achieved_bdp,
    init_spray, update_ecn_bitmap, choose_path,
    init_receiver, receiver_on_data,
)
from repro.core import ref

NET = NetworkSpec(link_gbps=400.0)
P = make_strack_params(NET)
P_SMALL = make_strack_params(NET, max_paths=16)


# --------------------------------------------------------------------------- #
# Algorithm 2 — adaptive load balancing
# --------------------------------------------------------------------------- #

lb_ops = st.lists(
    st.one_of(
        st.tuples(st.just("ack"), st.booleans(), st.integers(0, 15)),
        st.tuples(st.just("choose"), st.floats(0.5, 120.0),
                  st.floats(0.0, 100.0)),
    ),
    min_size=1, max_size=60,
)


@settings(max_examples=60, deadline=None)
@given(lb_ops)
def test_lb_matches_ref(ops):
    p = P_SMALL
    js = init_spray(p)
    rs = ref.SprayState(p)
    now = 0.0
    jit_update = jax.jit(update_ecn_bitmap)
    jit_choose = jax.jit(choose_path, static_argnums=1)
    for op in ops:
        if op[0] == "ack":
            _, ecn, path = op
            js = jit_update(js, jnp.asarray(ecn), jnp.asarray(path))
            rs.update_ecn_bitmap(ecn, path)
        else:
            _, cwnd, dt = op
            now += dt
            got, js = jit_choose(js, p, jnp.float32(cwnd), jnp.float32(now))
            want = rs.choose_path(cwnd, now)
            assert int(got) == want, (op, rs.bitmap, js.bitmap)
            assert [int(b) for b in js.bitmap] == rs.bitmap


def test_lb_all_marked_returns_cleared_head():
    """All-marked bitmap: Algo 2 clears the first skipped bit and wraps."""
    p = P_SMALL
    rs = ref.SprayState(p)
    for i in range(p.max_paths):
        rs.update_ecn_bitmap(True, i)
    got = rs.choose_path(4.0, now=0.0)  # paths = max(8, 2*4) = 8
    assert got == 1  # rr was 0 -> c0 = 1, cleared and reused after wrap
    assert rs.bitmap[1] == 0


def test_lb_prefers_ecn_free_ack_path():
    p = P_SMALL
    rs = ref.SprayState(p)
    rs.update_ecn_bitmap(False, 11)
    assert rs.choose_path(50.0, now=0.0) == 11  # reuse clean path at once


# --------------------------------------------------------------------------- #
# Algorithms 3 & 4 — congestion control
# --------------------------------------------------------------------------- #

cc_ops = st.lists(
    st.tuples(
        st.booleans(),                 # ecn
        st.floats(0.0, 120.0),         # measured qdelay (us)
        st.floats(0.0, 4096.0 * 4),    # acked bytes
        st.booleans(),                 # ack_for_probe
        st.floats(0.05, 30.0),         # dt
    ),
    min_size=1, max_size=80,
)


@settings(max_examples=60, deadline=None)
@given(cc_ops)
def test_cc_matches_ref(ops):
    p = P
    jcc = init_cc(p)
    rcc = ref.CCState(p)
    now = 0.0
    jit_bdp = jax.jit(update_achieved_bdp, static_argnums=1)
    jit_adj = jax.jit(adjust_cwnd, static_argnums=1)
    for ecn, delay, acked, probe, dt in ops:
        now += dt
        jcc = jit_bdp(jcc, p, jnp.float32(acked), jnp.asarray(probe),
                      jnp.float32(now))
        achieved = rcc.update_achieved_bdp(acked, probe, now)
        jcc = jit_adj(jcc, p, jnp.asarray(ecn), jnp.float32(delay),
                      jnp.float32(now))
        rcc.adjust_cwnd(ecn, delay, achieved, now)
        assert float(jcc.cwnd) == pytest.approx(rcc.cwnd, rel=2e-5), (
            ecn, delay, now)
        assert float(jcc.avg_delay) == pytest.approx(rcc.avg_delay, rel=2e-5,
                                                     abs=1e-4)
        assert float(jcc.achieved_bdp_pkts) == pytest.approx(
            rcc.achieved_bdp_pkts, rel=2e-5, abs=1e-4)


def test_cc_quadrants():
    """The four scenarios of Fig. 5."""
    p = P
    # 1: no ECN, low RTT -> proportional increase toward max.
    cc = ref.CCState(p)
    cc.cwnd = 10.0
    cc.adjust_cwnd(False, 0.0, 0.0, now=1.0)
    assert cc.cwnd > 10.0
    # 2: ECN, low RTT -> window unchanged (path switch handles it).
    cc = ref.CCState(p)
    cc.cwnd = 10.0
    cc.adjust_cwnd(True, 0.0, 0.0, now=0.1)  # can_fairness False: dt<base_rtt
    assert cc.cwnd == pytest.approx(10.0)
    # 3: high avg RTT -> multiplicative decrease.
    cc = ref.CCState(p)
    cc.cwnd = 50.0
    cc.avg_delay = 4 * p.target_qdelay_us
    cc.adjust_cwnd(True, 2.5 * p.target_qdelay_us, 50.0, now=100.0)
    assert cc.cwnd < 50.0
    # 3a: very high RTT + tiny achievedBDP -> jump to achievedBDP.
    cc = ref.CCState(p)
    cc.cwnd = 80.0
    cc.avg_delay = 10 * p.target_qdelay_us
    cc.adjust_cwnd(True, 4 * p.target_qdelay_us, 2.0, now=100.0)
    assert cc.cwnd == pytest.approx(2.0 + p.eta_pkts)  # + fairness shuffle
    # 4: no ECN but very high RTT -> additive increase (anti-starvation).
    cc = ref.CCState(p)
    cc.cwnd = 10.0
    cc.adjust_cwnd(False, 4 * p.target_qdelay_us, 0.0, now=0.1)
    assert cc.cwnd == pytest.approx(10.0 + p.beta_pkts / 10.0)


def test_achieved_bdp_window_clears():
    p = P
    cc = ref.CCState(p)
    cc.update_achieved_bdp(4096.0 * 10, False, now=1.0)
    assert cc.achieved_bdp_pkts == 0.0          # window not elapsed
    got = cc.update_achieved_bdp(4096.0 * 5, False,
                                 now=1.0 + p.base_rtt_us + p.target_qdelay_us + 1)
    assert got == pytest.approx(15.0)           # 15 pkts delivered
    assert cc.rx_count_bytes == 0.0


# --------------------------------------------------------------------------- #
# Receiver reliability — JAX fixed-window vs oracle
# --------------------------------------------------------------------------- #

@settings(max_examples=40, deadline=None)
@given(st.permutations(list(range(24))),
       st.integers(0, 7))
def test_receiver_matches_ref(order, drop_mod):
    """Random arrival order with some drops: EPSN/ooo/bytes must match."""
    p = P
    total = 24
    jr = init_receiver(total)
    rr = ref.STrackReceiver(p, total)
    jit_rx = jax.jit(receiver_on_data, static_argnums=1)
    for k, psn in enumerate(order):
        if drop_mod and psn % 7 == drop_mod % 7 and psn % 2 == 0:
            continue  # dropped packet
        pkt = ref.Packet(ref.DATA, 0, psn, p.mtu_bytes, entropy=3, ts=float(k))
        sack_ref = rr.on_data(pkt, now=float(k))
        jr, sack_jax = jit_rx(
            jr, p, jnp.int32(psn), jnp.float32(p.mtu_bytes),
            jnp.asarray(False), jnp.int32(3), jnp.float32(k),
            jnp.asarray(False))
        assert int(jr.epsn) == rr.epsn
        assert float(jr.bytes_recvd) == pytest.approx(rr.bytes_recvd)
        assert bool(sack_jax.valid) == (sack_ref is not None)
        if sack_ref is not None:
            assert int(sack_jax.epsn) == sack_ref.epsn
            assert int(sack_jax.ooo_cnt) == sack_ref.ooo_cnt
            assert int(sack_jax.sack_base) == sack_ref.sack_base
            got_bits = int(sum(int(b) << i
                               for i, b in enumerate(sack_jax.sack_bits)))
            assert got_bits == sack_ref.sack_bitmap
