"""End-to-end behaviour tests for the paper's system: the full
event-driven fabric + STrack vs RoCEv2, and the collective layer."""
import pytest

from repro.collective.algorithms import multi_job
from repro.core.params import NetworkSpec
from repro.sim.events import NetSim
from repro.sim.topology import full_bisection, with_link_failures
from repro.sim.workloads import (TraceRunner, incast_scenario,
                                 permutation_scenario, run_scenario_on_sim)


NET = NetworkSpec(link_gbps=400.0)


def test_permutation_strack_beats_roce():
    msg = 2 * 2 ** 20
    fct = {}
    for tr in ("strack", "roce"):
        sim = NetSim(full_bisection(4, 4), NET, transport=tr, seed=1)
        sc = permutation_scenario(sim.topo, msg, net=NET)
        fct[tr] = run_scenario_on_sim(sim, sc, until=1e6)["max_fct"]
    assert fct["strack"] < fct["roce"]


def test_permutation_all_complete_with_link_failures():
    topo = with_link_failures(full_bisection(4, 4), n_failed=4,
                              n_tors_affected=2, seed=3)
    sim = NetSim(topo, NET, transport="strack", seed=1)
    sc = permutation_scenario(sim.topo, 512 * 2 ** 10, net=NET)
    res = run_scenario_on_sim(sim, sc, until=1e6)
    assert res["unfinished"] == 0


def test_incast_parity_lossy_vs_lossless():
    """Fig 19: STrack (lossy) must stay within ~1.5x of lossless RoCE."""
    fct = {}
    for tr in ("strack", "roce"):
        sim = NetSim(full_bisection(4, 4), NET, transport=tr, seed=0)
        sc = incast_scenario(sim.topo, 8, 2 * 2 ** 20, net=NET, seed=0)
        r = run_scenario_on_sim(sim, sc, until=4e6)
        assert r["unfinished"] == 0
        fct[tr] = r["max_fct"]
    assert fct["strack"] < 1.5 * fct["roce"], fct


def test_strack_drops_recovered_roce_lossless():
    sim = NetSim(full_bisection(4, 4), NET, transport="strack", seed=0)
    sc = incast_scenario(sim.topo, 8, 2 * 2 ** 20, net=NET, seed=0)
    r = run_scenario_on_sim(sim, sc, until=4e6)
    assert r["drops"] > 0 and r["unfinished"] == 0   # lossy but reliable
    sim = NetSim(full_bisection(4, 4), NET, transport="roce", seed=0)
    r = run_scenario_on_sim(sim, sc, until=4e6)
    assert r["drops"] == 0                            # PFC keeps it lossless


@pytest.mark.slow
@pytest.mark.parametrize("algo", ["ring", "dbt", "hd", "a2a"])
def test_collectives_complete_both_transports(algo):
    for tr in ("strack", "roce"):
        sim = NetSim(full_bisection(4, 4), NET, transport=tr, seed=0)
        kw = dict(window=4) if algo == "a2a" else {}
        msgs, placement = multi_job(algo, 2, 8, 16, 512 * 2 ** 10, **kw)
        res = TraceRunner(sim, msgs, placement).run(until=1e7)
        assert res["finished_groups"] == res["total_groups"], (algo, tr)


def test_ecn_signal_leads_rtt():
    """Fig 4: the first ECN-marked ACK precedes any measurable RTT rise."""
    sim = NetSim(full_bisection(4, 8), NET, transport="strack", seed=0)
    sim.ack_log = []
    run_scenario_on_sim(sim, incast_scenario(sim.topo, 16, 1 * 2 ** 20,
                                             net=NET, seed=0), until=2e6)
    base = min(r for *_, r in sim.ack_log)
    t_ecn = next(t for t, _, e, _ in sim.ack_log if e)
    t_rtt = next((t for t, _, _, r in sim.ack_log if r > 1.5 * base),
                 float("inf"))
    assert t_ecn <= t_rtt
