"""Runtime substrate tests: optimizer, data pipeline, checkpoint/restart
(fault tolerance), gradient compression, serving."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.runtime import checkpoint as ckpt
from repro.runtime.data import DataConfig, SyntheticDataset
from repro.runtime.optimizer import (OptConfig, apply_updates, init_opt,
                                     quantize_int8, compress_grads,
                                     global_norm)
from repro.runtime.train import make_train_step

CFG = get_config("llama3-8b", smoke=True)
OPT = OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)


def small_state(seed=0):
    params = lm.init_params(jax.random.PRNGKey(seed), CFG)
    return params, init_opt(params, OPT)


def data(seed=0):
    return SyntheticDataset(DataConfig(vocab=CFG.vocab, seq=32,
                                       global_batch=4, seed=seed))


# --------------------------------------------------------------------------- #
# optimizer
# --------------------------------------------------------------------------- #

def test_train_loss_decreases():
    params, opt = small_state()
    ds = data()
    step = jax.jit(make_train_step(CFG, OPT))
    batch = ds.batch_at(0)   # overfit one batch
    losses = []
    for _ in range(20):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::5]


def test_grad_accum_matches_single_batch():
    params, opt = small_state()
    batch = data().batch_at(0)
    s1 = jax.jit(make_train_step(CFG, OPT))
    s4 = jax.jit(make_train_step(CFG, OPT, micro_batches=4))
    p1, o1, m1 = s1(params, opt, batch)
    p4, o4, m4 = s4(params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-3)
    # grads averaged over microbatches -> same update direction
    d1 = jax.tree.leaves(p1)[0] - jax.tree.leaves(params)[0]
    d4 = jax.tree.leaves(p4)[0] - jax.tree.leaves(params)[0]
    cos = float(jnp.sum(d1 * d4) /
                (jnp.linalg.norm(d1) * jnp.linalg.norm(d4) + 1e-12))
    assert cos > 0.98


def test_quantize_int8_roundtrip():
    g = jax.random.normal(jax.random.PRNGKey(0), (256, 16)) * 3.0
    q, scale = quantize_int8(g)
    err = jnp.abs(q.astype(jnp.float32) * scale - g)
    assert float(err.max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_converges():
    """Compressed updates with error feedback track the true sum."""
    key = jax.random.PRNGKey(1)
    total_true = jnp.zeros((64,))
    total_comp = jnp.zeros((64,))
    err = {"g": jnp.zeros((64,))}
    for i in range(50):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (64,)) * (1 + i % 3)
        deq, err = compress_grads({"g": g}, err)
        total_true += g
        total_comp += deq["g"]
    # residual is bounded by one quantisation step, not growing
    resid = float(jnp.abs(total_true - total_comp).max())
    assert resid < 0.5


def test_grad_compress_training_still_learns():
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=50,
                        grad_compress=True)
    params = lm.init_params(jax.random.PRNGKey(0), CFG)
    opt = init_opt(params, opt_cfg)
    batch = data().batch_at(0)
    step = jax.jit(make_train_step(CFG, opt_cfg))
    losses = []
    for _ in range(15):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


# --------------------------------------------------------------------------- #
# data pipeline
# --------------------------------------------------------------------------- #

def test_data_deterministic_and_resumable():
    ds1 = data()
    b0 = next(ds1)
    b1 = next(ds1)
    state = ds1.state_dict()
    b2 = next(ds1)
    ds2 = data()
    ds2.load_state_dict(state)
    b2b = next(ds2)
    np.testing.assert_array_equal(np.asarray(b2["tokens"]),
                                  np.asarray(b2b["tokens"]))
    assert not np.array_equal(np.asarray(b0["tokens"]),
                              np.asarray(b1["tokens"]))


def test_labels_are_next_tokens():
    b = data().batch_at(7)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# --------------------------------------------------------------------------- #
# checkpoint / restart (fault tolerance)
# --------------------------------------------------------------------------- #

def test_checkpoint_roundtrip(tmp_path):
    params, opt = small_state()
    d = str(tmp_path)
    ckpt.save(d, 3, {"params": params, "opt": opt},
              extra={"data": {"step": 3, "seed": 0}})
    assert ckpt.latest_step(d) == 3
    restored, extra = ckpt.restore(d, 3, {"params": params, "opt": opt})
    assert extra["data"]["step"] == 3
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_on_crash(tmp_path):
    """A partially-written checkpoint must never shadow a complete one."""
    params, opt = small_state()
    d = str(tmp_path)
    ckpt.save(d, 1, {"params": params})
    # simulate a crashed writer: stale tmp dir left behind
    os.makedirs(os.path.join(d, "step_00000002.tmp"), exist_ok=True)
    with open(os.path.join(d, "step_00000002.tmp", "junk.npy"), "w") as f:
        f.write("partial")
    assert ckpt.latest_step(d) == 1   # tmp is invisible
    ckpt.save(d, 2, {"params": params})   # and overwriting it works
    assert ckpt.latest_step(d) == 2


def test_restart_is_bit_exact(tmp_path):
    """Kill-and-resume training reproduces the uninterrupted run exactly."""
    d = str(tmp_path)
    step_fn = jax.jit(make_train_step(CFG, OPT))

    # uninterrupted: 6 steps
    params, opt = small_state()
    ds = data()
    for _ in range(6):
        params, opt, m = step_fn(params, opt, next(ds))
    ref_leaf = np.asarray(jax.tree.leaves(params)[0])

    # interrupted at step 3 + restore + 3 more
    params, opt = small_state()
    ds = data()
    for _ in range(3):
        params, opt, m = step_fn(params, opt, next(ds))
    ckpt.save(d, 3, {"params": params, "opt": opt},
              extra={"data": ds.state_dict()})
    del params, opt, ds
    like_p, like_o = small_state()
    restored, extra = ckpt.restore(d, 3, {"params": like_p, "opt": like_o})
    ds2 = data()
    ds2.load_state_dict(extra["data"])
    params, opt = restored["params"], restored["opt"]
    for _ in range(3):
        params, opt, m = step_fn(params, opt, next(ds2))
    got_leaf = np.asarray(jax.tree.leaves(params)[0])
    np.testing.assert_array_equal(ref_leaf, got_leaf)


def test_elastic_reshard_restore(tmp_path):
    """Restore a checkpoint onto a different mesh (elastic scaling)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.sharding import param_specs, to_shardings
    params, _ = small_state()
    d = str(tmp_path)
    ckpt.save(d, 1, {"params": params})
    from repro.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    shardings = {"params": to_shardings(param_specs(params, mesh), mesh)}
    restored, _ = ckpt.restore(d, 1, {"params": params}, shardings=shardings)
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------- #
# serving
# --------------------------------------------------------------------------- #

def test_greedy_generate_runs():
    from repro.runtime.serve import greedy_generate
    params, _ = small_state()
    prompt = jnp.array([[1, 2, 3, 4]], jnp.int32)
    out = greedy_generate(params, CFG, prompt, max_new=5, cache_len=16)
    assert out.shape == (1, 5)
    assert np.all(np.asarray(out) >= 0)
    assert np.all(np.asarray(out) < CFG.vocab)


def test_prefill_matches_decode_last_logits():
    from repro.runtime.serve import make_prefill_step
    params, _ = small_state()
    B, T = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, T), 0, CFG.vocab)
    pre = make_prefill_step(CFG)(params, {"tokens": toks})
    cache = lm.init_cache(CFG, B, T)
    for t in range(T):
        logits, cache = lm.decode_step(params, cache, toks[:, t:t + 1],
                                       jnp.asarray(t, jnp.int32), CFG)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(logits),
                               rtol=0.15, atol=0.15)
