"""The observatory's metrics layer (repro.obs): registry render/parse
round-trip, the stdlib HTTP exporter, the BENCH_history.jsonl trend
gate, and perf.py's loud no-baseline fallback."""
import json
import threading
import urllib.request

import pytest

from repro.obs.exporter import CONTENT_TYPE, make_server
from repro.obs.metrics import (MetricsRegistry, parse_prometheus,
                               render_prometheus)
from repro.obs.trend import (append_run, gate_and_append, load_history,
                             record_from_report, trend_problems)


# --------------------------------------------------------------------------- #
# MetricsRegistry + text exposition round trip
# --------------------------------------------------------------------------- #

def _registry():
    reg = MetricsRegistry()
    reg.declare("strack_drops_total", "packets dropped", "counter")
    reg.inc("strack_drops_total", 5)
    reg.inc("strack_drops_total", 2)
    reg.declare("strack_fct_us", "per-tenant FCT", "gauge")
    reg.set("strack_fct_us", 12.5, tenant="train_a", quantile="p99")
    reg.set("strack_fct_us", 3.25, tenant='odd"name\\x', quantile="p50")
    reg.set("strack_qdepth_max_pkts", 17)          # auto-declared gauge
    return reg


def test_render_parse_round_trip():
    reg = _registry()
    text = render_prometheus(reg)
    parsed = parse_prometheus(text)
    assert parsed[("strack_drops_total", ())] == 7.0
    assert parsed[("strack_fct_us", (("quantile", "p99"),
                                     ("tenant", "train_a")))] == 12.5
    assert parsed[("strack_fct_us", (("quantile", "p50"),
                                     ("tenant", 'odd"name\\x')))] == 3.25
    assert parsed[("strack_qdepth_max_pkts", ())] == 17.0
    assert len(parsed) == 4


def test_render_emits_help_and_type_lines():
    text = render_prometheus(_registry())
    lines = text.splitlines()
    assert "# HELP strack_drops_total packets dropped" in lines
    assert "# TYPE strack_drops_total counter" in lines
    assert "# TYPE strack_fct_us gauge" in lines
    # TYPE precedes the samples of its metric (exposition format rule)
    assert lines.index("# TYPE strack_drops_total counter") < \
        lines.index("strack_drops_total 7")


def test_registry_rejects_bad_names_and_redeclares():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.declare("bad name")
    with pytest.raises(ValueError):
        reg.declare("x", type="histogram")
    reg.declare("ok_total", type="counter")
    with pytest.raises(ValueError):
        reg.declare("ok_total", type="gauge")
    with pytest.raises(ValueError):
        reg.set("m", 1.0, **{"bad-label": "v"})


def test_parser_rejects_undeclared_and_garbage():
    with pytest.raises(ValueError):
        parse_prometheus("strack_x 1\n")           # no TYPE line
    with pytest.raises(ValueError):
        parse_prometheus("# TYPE strack_x gauge\nstrack_x one\n")
    with pytest.raises(ValueError):
        parse_prometheus("# TYPE strack_x spline\nstrack_x 1\n")
    # comments and blank lines are fine
    assert parse_prometheus("\n# a comment\n# TYPE a gauge\na 1\n") == \
        {("a", ()): 1.0}


# --------------------------------------------------------------------------- #
# the stdlib exporter
# --------------------------------------------------------------------------- #

def test_exporter_serves_metrics_file(tmp_path):
    prom = tmp_path / "m.prom"
    prom.write_text(render_prometheus(_registry()))
    srv = make_server(str(prom), port=0)           # ephemeral port
    port = srv.server_address[1]
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    try:
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics") as r:
            assert r.status == 200
            assert r.headers["Content-Type"] == CONTENT_TYPE
            body = r.read().decode()
        assert parse_prometheus(body)[("strack_drops_total", ())] == 7.0
        # scrapes re-read the file: a soak's periodic dumps show live
        prom.write_text("# TYPE live_gauge gauge\nlive_gauge 1\n")
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics") as r:
            assert "live_gauge" in r.read().decode()
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"http://127.0.0.1:{port}/nope")
        assert ei.value.code == 404
    finally:
        srv.shutdown()
        srv.server_close()


# --------------------------------------------------------------------------- #
# the cross-PR trend gate
# --------------------------------------------------------------------------- #

def _rec(**scenarios):
    return {"utc": "t", "jax": "j", "backend": "cpu",
            "scenarios": scenarios}


def test_trend_gate_catches_slow_boil_regression(tmp_path):
    hist = tmp_path / "BENCH_history.jsonl"
    append_run(str(hist), _rec(perm=1000.0))
    # each step is within a 20% snapshot gate of the last...
    append_run(str(hist), _rec(perm=880.0))
    append_run(str(hist), _rec(perm=780.0))
    history = load_history(str(hist))
    assert len(history) == 3
    # ...but the trajectory gate compares against the best-ever run
    assert trend_problems(history, _rec(perm=700.0)) != []
    assert trend_problems(history, _rec(perm=950.0)) == []
    # a brand-new scenario needs no baseline
    assert trend_problems(history, _rec(novel=1.0)) == []


def test_trend_tolerates_missing_and_corrupt_history(tmp_path, capsys):
    assert load_history(str(tmp_path / "absent.jsonl")) == []
    hist = tmp_path / "h.jsonl"
    hist.write_text('{"scenarios": {"a": 10.0}}\n'
                    "NOT JSON AT ALL\n"
                    '["not", "a", "record"]\n'
                    '{"scenarios": {"a": 12.0}}\n')
    history = load_history(str(hist))
    assert [r["scenarios"]["a"] for r in history] == [10.0, 12.0]
    err = capsys.readouterr().err
    assert "corrupt line skipped" in err and "malformed record" in err


def test_gate_and_append_records_even_regressions(tmp_path):
    hist = tmp_path / "h.jsonl"
    report = {"meta": {"utc": "t", "jax": "j", "backend": "cpu"},
              "scenarios": {"perm": {"warp": {"ticks_per_s": 1000.0}}}}
    assert gate_and_append(str(hist), report) == []
    bad = {"meta": report["meta"],
           "scenarios": {"perm": {"warp": {"ticks_per_s": 100.0}}}}
    problems = gate_and_append(str(hist), bad)
    assert problems and "below the best run" in problems[0]
    assert len(load_history(str(hist))) == 2   # the bad run is recorded


def test_record_from_report_skips_malformed_rows():
    rec = record_from_report(
        {"meta": {"utc": "t"},
         "scenarios": {"ok": {"warp": {"ticks_per_s": 5.0}},
                       "broken": {"warp": {}},
                       "worse": "not a dict"}})
    assert rec["scenarios"] == {"ok": 5.0}


# --------------------------------------------------------------------------- #
# perf.py satellite: loud no-baseline fallback
# --------------------------------------------------------------------------- #

def test_perf_load_baseline_fallbacks(tmp_path, capsys):
    from benchmarks.perf import _load_baseline
    assert _load_baseline(str(tmp_path / "missing.json")) is None
    assert "no baseline" in capsys.readouterr().err
    corrupt = tmp_path / "corrupt.json"
    corrupt.write_text('{"scenarios": TRUNCATED')
    assert _load_baseline(str(corrupt)) is None
    assert "unreadable" in capsys.readouterr().err
    scalar = tmp_path / "scalar.json"
    scalar.write_text("5")
    assert _load_baseline(str(scalar)) is None
    assert "not a JSON object" in capsys.readouterr().err
    good = tmp_path / "good.json"
    good.write_text(json.dumps({"scenarios": {}}))
    assert _load_baseline(str(good)) == {"scenarios": {}}
