"""Shared pytest plumbing: repo-root imports, the --update-golden flow,
and the tier1 / slow / fuzz marker registration (see pytest.ini)."""
import sys
from pathlib import Path

import pytest

# benchmarks/ (the perf harness whose JSON schema check is unit-tested)
# lives at the repo root, which pytest does not put on sys.path when the
# tests run from an installed-src layout.
ROOT = Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

GOLDEN_DIR = Path(__file__).resolve().parent / "golden"


def pytest_addoption(parser):
    parser.addoption(
        "--update-golden", action="store_true", default=False,
        help="rewrite tests/golden/*.json from the current fabric results "
             "instead of asserting against them")


@pytest.fixture(scope="session")
def update_golden(request) -> bool:
    return bool(request.config.getoption("--update-golden"))


@pytest.fixture(scope="session")
def golden_dir() -> Path:
    return GOLDEN_DIR
