"""Per-architecture smoke tests: reduced config, one loss eval + one decode
step on CPU, asserting shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_config
from repro.models import lm
from repro.models.config import ModelConfig


def make_batch(cfg: ModelConfig, key, B=2, T=32):
    ks = jax.random.split(key, 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, T), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (B, T), 0, cfg.vocab),
    }
    if cfg.kind == "vlm":
        batch["vis_embed"] = jax.random.normal(
            ks[2], (B, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
    if cfg.kind == "encdec":
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", all_archs())
def test_smoke_loss(arch):
    cfg = get_config(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss = jax.jit(lambda p, b: lm.lm_loss(p, b, cfg))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    # a random model should sit near ln(vocab)
    assert 0.1 * np.log(cfg.vocab) < float(loss) < 3 * np.log(cfg.vocab)


@pytest.mark.parametrize("arch", all_archs())
def test_smoke_grads(arch):
    cfg = get_config(arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1), B=1, T=16)
    g = jax.jit(jax.grad(lambda p: lm.lm_loss(p, batch, cfg)))(params)
    leaves = jax.tree.leaves(g)
    assert leaves
    for leaf in leaves:
        assert np.all(np.isfinite(np.asarray(leaf))), arch


@pytest.mark.parametrize("arch", all_archs())
def test_smoke_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 64
    cache = lm.init_cache(cfg, B, S)
    if cfg.kind == "encdec":
        enc_out = lm.encode(
            params,
            jax.random.normal(jax.random.PRNGKey(2),
                              (B, cfg.enc_seq, cfg.d_model), jnp.float32),
            cfg)
        cache["enc_out"] = enc_out.astype(cache["enc_out"].dtype)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))
    tok = jnp.zeros((B, 1), jnp.int32)
    for pos in range(3):
        logits, cache = step(params, cache, tok,
                             jnp.asarray(pos, jnp.int32))
        assert logits.shape == (B, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits))), arch
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


def test_decode_matches_forward_dense():
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = get_config("llama3-8b", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, T = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0, cfg.vocab)
    # forward path
    x = lm.embed_tokens(params, toks, cfg)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    hidden, _ = lm.forward_hidden(params, x, pos, cfg)
    w = lm.lm_head_weight(params, cfg)
    full_logits = (hidden @ w.astype(hidden.dtype)).astype(jnp.float32)
    # decode path
    cache = lm.init_cache(cfg, B, T)
    outs = []
    for t in range(T):
        logits, cache = lm.decode_step(params, cache, toks[:, t:t + 1],
                                       jnp.asarray(t, jnp.int32), cfg)
        outs.append(logits)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=0.15, atol=0.15)


def test_decode_matches_forward_ssm():
    cfg = get_config("mamba2-2.7b", smoke=True)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    B, T = 1, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T), 0, cfg.vocab)
    x = lm.embed_tokens(params, toks, cfg)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    hidden, _ = lm.forward_hidden(params, x, pos, cfg)
    w = lm.lm_head_weight(params, cfg)
    full_logits = (hidden @ w.astype(hidden.dtype)).astype(jnp.float32)
    cache = lm.init_cache(cfg, B, T)
    outs = []
    for t in range(T):
        logits, cache = lm.decode_step(params, cache, toks[:, t:t + 1],
                                       jnp.asarray(t, jnp.int32), cfg)
        outs.append(logits)
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=0.2, atol=0.2)
