"""Fault tolerance: injected failures + restart must reproduce the
uninterrupted run exactly; elasticity rules."""
import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.runtime.data import DataConfig, SyntheticDataset
from repro.runtime.elastic import (SupervisorConfig, TrainSupervisor,
                                   scale_batch_rule)
from repro.runtime.optimizer import OptConfig, init_opt
from repro.runtime.train import make_train_step

CFG = get_config("qwen3-4b", smoke=True)
OPT = OptConfig(lr=1e-3, warmup_steps=2, total_steps=100)


def setup(tmp, ckpt_every=4):
    params = lm.init_params(jax.random.PRNGKey(0), CFG)
    opt = init_opt(params, OPT)
    ds = SyntheticDataset(DataConfig(vocab=CFG.vocab, seq=16,
                                     global_batch=2, seed=3))
    step = jax.jit(make_train_step(CFG, OPT))
    sup = TrainSupervisor(SupervisorConfig(ckpt_dir=str(tmp),
                                           ckpt_every=ckpt_every),
                          (params, opt), ds, step)
    return sup


def test_failures_recovered_bit_exact(tmp_path):
    ref = setup(tmp_path / "a")
    (p_ref, _) = ref.run(10)

    # same run with two injected failures
    faulty = setup(tmp_path / "b")
    (p_got, _) = faulty.run(10, fail_at={3, 7})
    assert faulty.restarts == 2
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loss_log_monotone_progress(tmp_path):
    sup = setup(tmp_path)
    sup.run(8, fail_at={5})
    steps = [s for s, _ in sup.metrics_log]
    # every step 0..7 was eventually executed
    assert set(range(8)).issubset(set(steps))


def test_scale_batch_rule():
    assert scale_batch_rule(256, 8, 512, 256) == 16   # half chips -> 2x accum
    assert scale_batch_rule(256, 8, 256, 512) == 4
    assert scale_batch_rule(256, 1, 256, 999) == 1
