"""Sort-free ranking + active-set formulation (PR 6 hot-path rewrite).

``_rank_in_queue`` dropped its per-tick stable argsort for a chunked
scatter-add/segmented-count scan; the contract ALSO changed from
"meaningless values at non-flagged entries" to an explicit ``-1`` fill.
The property tests here pin both the new implementation and the retained
argsort reference against a straightforward O(M^2) lower-triangle oracle
across the edge cases that bit-exactness of the enqueue stage rides on
(empty, single, none/all flagged, duplicate qids, chunk-boundary sizes).

The active-set tests assert the observable-equivalence argument the
formulation rests on: excluding done or dep-gated flows from the NIC
lanes is BIT-exact because such flows are transition-silent, and the
program detects (and refuses to silently drop) a cap overflow.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.sim import fabric as F
from repro.sim.topology import full_bisection
from repro.sim.workloads import Message, RunConfig

pytestmark = pytest.mark.tier1


# ---------------------------------------------------------------------------
# _rank_in_queue property tests
# ---------------------------------------------------------------------------

def _rank_reference(qid: np.ndarray, flag: np.ndarray) -> np.ndarray:
    """O(M^2) lower-triangle oracle: rank of entry i = number of flagged
    same-queue entries strictly before it; -1 when not flagged."""
    m = qid.shape[0]
    ref = np.full(m, -1, np.int32)
    for i in range(m):
        if flag[i]:
            ref[i] = int(np.sum(flag[:i] & (qid[:i] == qid[i])))
    return ref


def _case(qid, flag, n_queues):
    qid = np.asarray(qid, np.int32)
    flag = np.asarray(flag, bool)
    ref = _rank_reference(qid, flag)
    new = np.asarray(F._rank_in_queue(jnp.asarray(qid), jnp.asarray(flag),
                                      n_queues))
    old = np.asarray(F._rank_in_queue_argsort(jnp.asarray(qid),
                                              jnp.asarray(flag)))
    assert np.array_equal(new, ref), (qid, flag, new, ref)
    assert np.array_equal(old, ref), (qid, flag, old, ref)


def test_rank_empty():
    _case([], [], 4)


def test_rank_single():
    _case([2], [True], 4)
    _case([2], [False], 4)


def test_rank_none_flagged():
    _case([0, 1, 2, 1], [False] * 4, 4)


def test_rank_all_flagged_duplicate_qids():
    _case([3, 3, 3, 3, 3], [True] * 5, 4)


def test_rank_mixed_duplicates():
    _case([0, 1, 0, 1, 0, 2], [True, False, True, True, True, True], 3)


@pytest.mark.parametrize("m", [1, 63, 64, 65, 200, 255, 256, 257,
                               300, 511, 512, 513])
def test_rank_chunk_boundaries(m):
    """Sizes straddling the _RANK_CHUNK block size (256): partial single
    blocks, exact multiples, and one-past boundaries where the cross-block
    cumsum base first kicks in."""
    rng = np.random.default_rng(m)
    n_queues = 17
    qid = rng.integers(0, n_queues, size=m).astype(np.int32)
    flag = rng.random(m) < 0.6
    _case(qid, flag, n_queues)


def test_rank_randomized():
    rng = np.random.default_rng(0)
    for _ in range(8):
        n_queues = int(rng.integers(1, 40))
        # one shape -> one jit trace; density varies per draw
        qid = rng.integers(0, n_queues, size=192).astype(np.int32)
        flag = rng.random(192) < rng.random()
        _case(qid, flag, n_queues)


# ---------------------------------------------------------------------------
# active-set formulation
# ---------------------------------------------------------------------------

TOPO = full_bisection(2, 4)


def _two_stage_trace():
    """4 then 4 dependency-chained messages: at most 5 flows are ever
    released & not-done at once, so active_cap=5 < N=8 genuinely takes
    the capped lane path."""
    msgs = [Message(mid=i, src=i, dst=(i + 4) % 8,
                    size=float(12288 + 4096 * i), deps=(), group=0)
            for i in range(4)]
    msgs += [Message(mid=4 + i, src=(i + 4) % 8, dst=i,
                     size=float(20480 + 4096 * i), deps=(i,), group=1)
             for i in range(4)]
    return msgs


def _run(msgs, n_ticks, **kw):
    kw.setdefault("trace_every", 0)
    cfg = F.FabricConfig(**kw)
    _, m = F.run_fabric_trace(TOPO, msgs, n_ticks, cfg)
    return m


@pytest.mark.parametrize("proto_kw", [
    dict(),                                    # strack adaptive
    dict(protocol="rocev2", pfc=True),         # lossless roce
    dict(time_warp=True),                      # event-horizon scan
])
def test_active_cap_bit_exact(proto_kw):
    msgs = _two_stage_trace()
    base = _run(msgs, 9000, **proto_kw)
    capped = _run(msgs, 9000, active_cap=5, **proto_kw)
    assert base["fct_us"] == capped["fct_us"]
    assert base["drops"] == capped["drops"]
    assert base["pauses"] == capped["pauses"]
    assert base["group_done_us"] == capped["group_done_us"]


def test_active_cap_overflow_raises():
    """A cap below the peak released&not-done count must raise, not
    silently stall the flows beyond the cap."""
    msgs = _two_stage_trace()
    with pytest.raises(RuntimeError, match="active_cap"):
        _run(msgs, 9000, active_cap=2)


def test_active_cap_at_or_above_n_disables():
    """cap >= n_flows degenerates to the plain every-flow-is-a-lane path
    (A is normalized to 0) — results identical, no overflow possible."""
    msgs = _two_stage_trace()
    base = _run(msgs, 9000)
    wide = _run(msgs, 9000, active_cap=64)
    assert base["fct_us"] == wide["fct_us"]


def test_active_cap_requires_no_trace():
    with pytest.raises(ValueError, match="trace"):
        _run(_two_stage_trace(), 9000, active_cap=5, trace_every=8,
             time_warp=False)


def test_runconfig_validates_active_cap():
    with pytest.raises(ValueError, match="active_cap"):
        RunConfig(active_cap=0)
    with pytest.raises(ValueError, match="no-trace"):
        RunConfig(active_cap=4, trace_every=16)


def test_act_overflow_is_final_key():
    """The overflow counter rides the single device_get like every other
    final-carry scalar (no extra sync)."""
    assert "act_overflow" in F._FINAL_KEYS
