"""Multi-queue jitted fabric vs the event-driven oracle (semantics parity)
plus the multipath regressions the single-queue simulator could never test:
ECMP bit-exactness, spray spreading over live uplinks, and adaptive spray
beating single-path pinning on an asymmetric (dead-link) fabric.
"""
import numpy as np
import pytest

from repro.core.params import NetworkSpec
from repro.sim.fabric import (ArrayTopo, FabricConfig, ecmp_mix, run_fabric,
                              summarize)

pytestmark = pytest.mark.tier1
from repro.sim.topology import FatTree, full_bisection
from repro.sim.workloads import (RunConfig, incast_scenario,
                                 permutation_scenario, run)

NET = NetworkSpec(link_gbps=400.0)
TOPO44 = full_bisection(4, 4)        # 16 hosts, 4 ToRs, 4 spines

# The fabric is a tick-quantised approximation of the event oracle;
# completion times must agree within this factor, drop counts within 2x.
# Tightened from (0.6, 1.6) by the per-hop latency pipeline: both
# backends now realize the same base RTT hop by hop (measured ratios
# ~0.95-1.06), so the band only covers tick quantisation + ECN dither.
FCT_TOL = (0.8, 1.25)


def _fct_ratio(fabric_res, events_res):
    return fabric_res["max_fct"] / events_res["max_fct"]


# --------------------------------------------------------------------------- #
# parity vs the oracle (acceptance: >=4 ToR / >=4 spine, incast+permutation)
# --------------------------------------------------------------------------- #

def test_incast_parity_vs_oracle():
    """8->1 incast, 512KB: drops happen on both backends and FCTs agree."""
    sc = incast_scenario(TOPO44, 8, 512 * 2 ** 10, net=NET)
    ev = run(sc, RunConfig(backend="events", until=2e6))
    fb = run(sc, RunConfig())
    assert ev["unfinished"] == 0 and fb["unfinished"] == 0
    r = _fct_ratio(fb, ev)
    assert FCT_TOL[0] < r < FCT_TOL[1], (fb["max_fct"], ev["max_fct"])
    # both lossy backends shed the incast burst in the same ballpark
    assert ev["drops"] > 0 and fb["drops"] > 0
    dr = fb["drops"] / ev["drops"]
    assert 0.5 < dr < 2.0, (fb["drops"], ev["drops"])


def test_permutation_parity_vs_oracle():
    """16-host permutation, 256KB: full-bisection fabric, no drops."""
    sc = permutation_scenario(TOPO44, 256 * 2 ** 10, net=NET, seed=0)
    ev = run(sc, RunConfig(backend="events", until=1e6))
    fb = run(sc, RunConfig())
    assert ev["unfinished"] == 0 and fb["unfinished"] == 0
    r = _fct_ratio(fb, ev)
    assert FCT_TOL[0] < r < FCT_TOL[1], (fb["max_fct"], ev["max_fct"])
    assert ev["drops"] == 0 and fb["drops"] == 0


# --------------------------------------------------------------------------- #
# multipath semantics
# --------------------------------------------------------------------------- #

def test_ecmp_matches_python_topology():
    """The jnp ECMP hash is bit-exact vs FatTree.ecmp_spine, dead links
    included."""
    import jax.numpy as jnp
    topo = FatTree(n_tor=4, hosts_per_tor=4, n_spine=4,
                   dead_links=frozenset({(0, 0), (0, 1), (2, 3)}))
    at = ArrayTopo.from_fat_tree(topo)
    srcs, dsts, ents = [], [], []
    for src in range(topo.n_hosts):
        for dst in range(0, topo.n_hosts, 3):
            if topo.same_tor(src, dst):
                continue
            for ent in (0, 1, 7, 63, 255):
                srcs.append(src), dsts.append(dst), ents.append(ent)
    got = np.asarray(at.ecmp_spine(jnp.asarray(srcs, jnp.int32),
                                   jnp.asarray(dsts, jnp.int32),
                                   jnp.asarray(ents, jnp.int32)))
    want = np.asarray([topo.ecmp_spine(s, d, e)
                       for s, d, e in zip(srcs, dsts, ents)])
    np.testing.assert_array_equal(got, want)
    # every chosen spine is a live uplink of the source ToR
    live = np.asarray(at.live_mask)
    tors = np.asarray(srcs) // topo.hosts_per_tor
    assert live[tors, got].all()


@pytest.fixture(scope="module")
def asymmetric_runs():
    """Permutation on a fabric with dead uplinks (>=2 live per ToR),
    adaptive spray vs fixed single-path pinning."""
    topo = FatTree(n_tor=4, hosts_per_tor=4, n_spine=4,
                   dead_links=frozenset({(0, 0), (0, 1), (1, 0)}))
    flows = permutation_scenario(topo, 512 * 2 ** 10, net=NET, seed=1).flows
    out = {}
    for mode in ("adaptive", "fixed"):
        final, m = run_fabric(topo, flows, 16000,
                              FabricConfig(net=NET, lb_mode=mode))
        out[mode] = (final, summarize(m))
    return topo, out


def test_adaptive_spray_beats_fixed_path_under_asymmetry(asymmetric_runs):
    """With dead links, Algorithm 2's spray must measurably beat pinning."""
    _, out = asymmetric_runs
    ad, fx = out["adaptive"][1], out["fixed"][1]
    assert ad["unfinished"] == 0 and fx["unfinished"] == 0
    assert ad["max_fct"] < 0.95 * fx["max_fct"], (ad["max_fct"],
                                                  fx["max_fct"])


def test_spray_uses_every_live_uplink(asymmetric_runs):
    """Adaptive spray spreads each ToR's traffic over ALL its live uplinks
    (the single-queue simulator could not represent this at all)."""
    topo, out = asymmetric_runs
    final = out["adaptive"][0]
    T, S = topo.n_tor, topo.n_spine
    served = np.asarray(final.qhead)[:T * S].reshape(T, S)
    for t in range(T):
        for s in range(S):
            if (t, s) in topo.dead_links:
                assert served[t, s] == 0, (t, s)
            else:
                assert served[t, s] > 0, (t, s)


def test_fixed_path_never_sprays(asymmetric_runs):
    """Single-path pinning sends each flow over exactly one uplink, so some
    live uplinks stay cold — the contrast that makes spray matter."""
    topo, out = asymmetric_runs
    final = out["fixed"][0]
    T, S = topo.n_tor, topo.n_spine
    served = np.asarray(final.qhead)[:T * S].reshape(T, S)
    n_flows = 16
    # at most one uplink per (src ToR) per flow -> <= n_flows warm uplinks
    assert (served > 0).sum() <= n_flows
    # and strictly fewer warm uplinks than adaptive spray lights up
    ad_served = np.asarray(out["adaptive"][0].qhead)[:T * S]
    assert (served > 0).sum() < (ad_served > 0).sum()


def test_per_hop_rtt_realizes_base_rtt():
    """The tentpole contract of the per-hop pipeline: a single 1-packet
    cross-ToR flow's FCT is one hop-exact base RTT on BOTH backends (the
    folded model could only promise this in aggregate), and a same-ToR
    flow — 2 store-and-forward hops instead of 4 — completes in about
    half that."""
    from repro.sim.workloads import RunConfig, Scenario, run
    tick = NET.mtu_serialize_us
    cross = Scenario.from_flows("one_cross", TOPO44, NET, [(0, 15, 1000.0)])
    fb = run(cross, RunConfig(backend="fabric"))
    ev = run(cross, RunConfig(backend="events", until=1e5))
    assert abs(fb["max_fct"] - NET.base_rtt_us) <= 5 * tick, fb["max_fct"]
    assert abs(ev["max_fct"] - NET.base_rtt_us) <= 5 * tick, ev["max_fct"]
    same = Scenario.from_flows("one_same", TOPO44, NET, [(0, 1, 1000.0)])
    fb_s = run(same, RunConfig(backend="fabric"))
    ev_s = run(same, RunConfig(backend="events", until=1e5))
    half = NET.base_rtt_us / 2
    assert abs(fb_s["max_fct"] - half) <= 5 * tick, fb_s["max_fct"]
    assert abs(ev_s["max_fct"] - half) <= 5 * tick, ev_s["max_fct"]


def test_ecmp_mix_matches_reference_scalar():
    from repro.sim.topology import _mix
    import jax.numpy as jnp
    for a, b, c in [(0, 0, 0), (1, 2, 3), (15, 7, 255), (123, 45, 63)]:
        got = int(ecmp_mix(jnp.int32(a), jnp.int32(b), jnp.int32(c)))
        assert got == _mix(a, b, c), (a, b, c)
