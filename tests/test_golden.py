"""Golden FCT regression gate: per-figure fabric summary snapshots.

Each case pins the headline numbers (max/avg FCT, drops, pauses, and the
collective completion time for grouped traces) of one figure-class
scenario — permutation / incast / ring allreduce / windowed all-to-all,
under STrack, RoCEv2 and the 4-QP striped RoCEv2 — against a checked-in
JSON snapshot in ``tests/golden/``.  Fidelity refactors that shift a
headline number fail HERE even when they stay inside the oracle-parity
bands, so intentional model changes must regenerate the snapshots:

    PYTHONPATH=src python -m pytest tests/test_golden.py --update-golden

and the diff reviewed like any other code change.  The fabric is
deterministic (deterministic ECN dither, hash/seeded entropy), so the
comparison is tight: exact ints, 1e-6 relative on floats.
"""
import json

import pytest

from repro.core.params import NetworkSpec
from repro.sim.faults import link_flap
from repro.sim.topology import full_bisection
from repro.sim.workloads import (RunConfig, collective_scenario,
                                 incast_scenario, permutation_scenario, run)

pytestmark = pytest.mark.tier1

NET400 = NetworkSpec(link_gbps=400.0)
NET100 = NetworkSpec(link_gbps=100.0)
TOPO44 = full_bisection(4, 4)
TOPO24 = full_bisection(2, 4)

#: Summary keys pinned by the snapshots (whichever the run reports).
GOLDEN_KEYS = ("max_fct", "avg_fct", "unfinished", "drops", "pauses",
               "max_collective_time", "finished_groups", "total_groups")


def _perm(**kw):
    return (permutation_scenario(TOPO44, 256 * 2 ** 10, net=NET400, seed=0),
            RunConfig(backend="fabric", **kw))


def _perm_flap(**kw):
    # canonical chaos case: one ToR-0 uplink flaps mid-run ([50, 400)
    # ticks) while the permutation is in flight, then recovers — pins the
    # blackhole + loss-recovery path (docs/robustness.md)
    return (permutation_scenario(TOPO44, 256 * 2 ** 10, net=NET400, seed=0),
            RunConfig(backend="fabric", faults=link_flap(0, 0, 50, 400),
                      **kw))


def _incast(**kw):
    return (incast_scenario(TOPO44, 8, 512 * 2 ** 10, net=NET400),
            RunConfig(backend="fabric", **kw))


def _ring(**kw):
    return (collective_scenario(TOPO24, "ring", 1, 8, 512 * 2 ** 10,
                                net=NET100, seed=0, chunk=32 * 2 ** 10),
            RunConfig(backend="fabric", **kw))


def _a2a(**kw):
    return (collective_scenario(TOPO24, "a2a", 2, 4, 256 * 2 ** 10,
                                net=NET100, seed=0, chunk=128 * 2 ** 10,
                                window=2),
            RunConfig(backend="fabric", **kw))


CASES = {
    "perm16_strack": lambda: _perm(),
    "perm16_roce": lambda: _perm(protocol="rocev2"),
    "perm16_flap_strack": lambda: _perm_flap(),
    "perm16_flap_roce": lambda: _perm_flap(protocol="rocev2"),
    "incast8_strack": lambda: _incast(),
    "incast8_roce": lambda: _incast(protocol="rocev2"),
    "ring8_strack": lambda: _ring(),
    "ring8_roce4": lambda: _ring(protocol="rocev2", subflows=4),
    "a2a_strack": lambda: _a2a(),
}


def _snapshot(res: dict) -> dict:
    return {k: res[k] for k in GOLDEN_KEYS if k in res}


@pytest.mark.parametrize("case", sorted(CASES))
def test_golden_fct(case, update_golden, golden_dir):
    sc, cfg = CASES[case]()
    snap = _snapshot(run(sc, cfg))
    path = golden_dir / f"{case}.json"
    if update_golden:
        golden_dir.mkdir(exist_ok=True)
        path.write_text(json.dumps(snap, indent=2, sort_keys=True) + "\n")
        pytest.skip(f"updated {path.name}")
    assert path.exists(), (
        f"missing golden fixture {path}; generate with "
        f"`pytest tests/test_golden.py --update-golden` and review the "
        f"numbers before checking them in")
    want = json.loads(path.read_text())
    assert set(snap) == set(want), (
        f"{case}: summary keys changed {sorted(want)} -> {sorted(snap)}; "
        f"regenerate the goldens if intentional")
    for k, v in sorted(want.items()):
        got = snap[k]
        if isinstance(v, float):
            assert got == pytest.approx(v, rel=1e-6), (case, k, got, v)
        else:
            assert got == v, (case, k, got, v)
