"""The observatory's workload generator + soak driver (sim/traffic.py):
seed determinism, structure invariance across epochs, arrival semantics
on both backends, per-tenant FCT attribution, and one-program soaks."""
import numpy as np
import pytest

from repro.core.params import NetworkSpec
from repro.sim.topology import full_bisection
from repro.sim.traffic import (InferenceTenant, TrainingJob, _u01, _u64,
                               mixed_scenario, soak, splitmix64)
from repro.sim.workloads import Message, RunConfig, Scenario, run

pytestmark = pytest.mark.tier1

NET = NetworkSpec(link_gbps=400.0)
TOPO44 = full_bisection(4, 4)        # 16 hosts, 4 ToRs, 4 spines

JOBS = [
    TrainingJob("job_ring", algo="ring", ranks=4,
                collective_bytes=64 * 2 ** 10, steps=2),
    TrainingJob("job_hd", algo="hd", ranks=4,
                collective_bytes=64 * 2 ** 10, start_tick=50),
]
TENANTS = [
    InferenceTenant("burst", n_flows=16, mean_interarrival_ticks=4.0,
                    size_bytes=8 * 2 ** 10, size_jitter=0.5, n_targets=2),
]


def _mix(seed=3, epoch=0, jobs=JOBS, tenants=TENANTS, topo=TOPO44):
    return mixed_scenario(topo, jobs, tenants, net=NET, seed=seed,
                          epoch=epoch)


# --------------------------------------------------------------------------- #
# the counter PRNG + generator determinism
# --------------------------------------------------------------------------- #

def test_splitmix64_reference_values():
    """Known-answer test against the reference splitmix64 stream from
    seed 0 (Steele et al. / xoshiro.di.unimi.it reference code)."""
    state, outs = 0, []
    for _ in range(3):
        state = (state + 0x9E3779B97F4A7C15) % 2 ** 64
        outs.append(splitmix64(state - 0x9E3779B97F4A7C15))
    assert outs == [0xE220A8397B1DCDAF, 0x6E789E6AA1B965F4,
                    0x06C45D188009454F]


def test_counter_prng_is_stateless_and_keyed():
    assert _u64(1, 2, 3) == _u64(1, 2, 3)
    assert _u64(1, 2, 3) != _u64(1, 3, 2)
    assert _u64(1, 2, 3) != _u64(2, 2, 3)
    us = [_u01(0, i) for i in range(1000)]
    assert all(0.0 <= u < 1.0 for u in us)
    assert 0.4 < sum(us) / len(us) < 0.6


def test_same_seed_bit_identical_trace():
    sc_a, tog_a = _mix(seed=7)
    sc_b, tog_b = _mix(seed=7)
    assert sc_a.messages == sc_b.messages
    assert tog_a == tog_b


def test_different_seeds_distinct_arrivals_and_placement():
    sc_a, _ = _mix(seed=7)
    sc_b, _ = _mix(seed=8)
    assert [m.arrival for m in sc_a.messages] != \
        [m.arrival for m in sc_b.messages]
    assert [(m.src, m.dst) for m in sc_a.messages] != \
        [(m.src, m.dst) for m in sc_b.messages]


def test_epochs_resample_data_but_not_structure():
    """Epoch changes burst arrivals/sources/sizes (program data) while
    the trace structure — the fabric's program-cache key — is frozen."""
    sc0, _ = _mix(epoch=0)
    sc1, _ = _mix(epoch=1)
    assert [(m.mid, m.deps, m.group) for m in sc0.messages] == \
        [(m.mid, m.deps, m.group) for m in sc1.messages]
    assert [m.arrival for m in sc0.messages] != \
        [m.arrival for m in sc1.messages]
    # job placement (and so every job src/dst) is epoch-invariant
    n_job_msgs = sum(1 for m in sc0.messages if m.group < len(JOBS))
    assert [(m.src, m.dst) for m in sc0.messages[:n_job_msgs]] == \
        [(m.src, m.dst) for m in sc1.messages[:n_job_msgs]]


def test_burst_arrivals_are_open_loop():
    sc, tog = _mix()
    g = next(g for g, n in tog.items() if n == "burst")
    arr = [m.arrival for m in sc.messages if m.group == g]
    assert all(b > a for a, b in zip(arr, arr[1:])), \
        "burst arrivals must strictly advance"
    assert all(not m.deps for m in sc.messages if m.group == g)


def test_default_ticks_covers_late_arrivals():
    sc = Scenario(name="late", topo=TOPO44, net=NET, messages=(
        Message(mid=0, src=0, dst=5, size=64 * 2 ** 10, arrival=50_000),))
    assert sc.default_ticks() > 50_000


# --------------------------------------------------------------------------- #
# arrival semantics on the fabric: warp == dense, and oracle parity
# --------------------------------------------------------------------------- #

def test_arrival_warp_vs_dense_bit_exact():
    sc, _ = _mix(seed=5)
    dense = run(sc, RunConfig(time_warp=False))
    warp = run(sc, RunConfig(time_warp=True))
    for k in ("max_fct", "avg_fct", "unfinished", "drops", "pauses",
              "max_collective_time", "finished_groups"):
        assert dense[k] == warp[k] or (
            dense[k] != dense[k] and warp[k] != warp[k]), (k, dense[k],
                                                           warp[k])


def test_arrival_fabric_vs_events_parity():
    """A pure open-loop burst trace: both backends honour the arrival
    schedule, so FCTs agree within the parity band."""
    sc, _ = mixed_scenario(TOPO44, [], TENANTS, net=NET, seed=2)
    fb = run(sc, RunConfig())
    ev = run(sc, RunConfig(backend="events", until=1e7))
    assert fb["unfinished"] == 0 and ev["unfinished"] == 0
    r = fb["max_fct"] / ev["max_fct"]
    assert 0.7 < r < 1.4, (fb["max_fct"], ev["max_fct"])


def test_events_backend_reports_msg_fct():
    sc, _ = _mix(seed=4)
    ev = run(sc, RunConfig(backend="events", until=1e7))
    assert set(ev["msg_fct"]) == {m.mid for m in sc.messages}
    assert all(f > 0 for f in ev["msg_fct"].values())


# --------------------------------------------------------------------------- #
# per-tenant FCT attribution
# --------------------------------------------------------------------------- #

def test_tenant_fct_matches_solo_runs():
    """Two single-ToR ring jobs on disjoint hosts never contend (one
    message per host at a time, no shared queues), so each tenant's FCT
    percentiles in the mixed run equal its solo run bit-exactly."""
    job_a = TrainingJob("a", algo="ring", ranks=4,
                        collective_bytes=64 * 2 ** 10,
                        hosts=(0, 1, 2, 3))
    job_b = TrainingJob("b", algo="ring", ranks=4,
                        collective_bytes=64 * 2 ** 10,
                        hosts=(4, 5, 6, 7))
    mixed, tog = mixed_scenario(TOPO44, [job_a, job_b], [], net=NET,
                                seed=0)
    n_ticks = mixed.default_ticks()
    cfg = RunConfig(n_ticks=n_ticks)
    res = run(mixed, cfg)
    assert res["unfinished"] == 0
    for g, name in tog.items():
        solo_sc, _ = mixed_scenario(
            TOPO44, [job_a if name == "a" else job_b], [], net=NET, seed=0)
        solo = run(solo_sc, cfg)
        mrow, srow = res["tenant_fct"][g], solo["tenant_fct"][0]
        assert mrow == srow, (name, mrow, srow)


def test_tenant_fct_counts_every_message():
    sc, tog = _mix(seed=9)
    res = run(sc, RunConfig())
    assert set(res["tenant_fct"]) == set(tog)
    assert sum(r["count"] for r in res["tenant_fct"].values()) == \
        len(sc.messages)
    for row in res["tenant_fct"].values():
        assert row["p50"] <= row["p99"] <= row["max"]


# --------------------------------------------------------------------------- #
# the soak driver
# --------------------------------------------------------------------------- #

def test_soak_reuses_one_program_and_carries_counters(tmp_path):
    from repro.obs.metrics import MetricsRegistry, parse_prometheus
    reg = MetricsRegistry()
    out = tmp_path / "soak.prom"
    res = soak(TOPO44, JOBS, TENANTS, epochs=2, net=NET, seed=3,
               registry=reg, out_path=str(out))
    # <= 1: the program cache is process-global, so an earlier test may
    # have already compiled the structure-identical warp program
    assert res["program_builds"] <= 1, \
        "structure-identical epochs must share one compiled program"
    assert res["totals"]["unfinished"] == 0
    assert res["totals"]["messages"] == 2 * len(_mix()[0].messages)
    assert len(res["epoch_rows"]) == 2
    assert set(res["per_tenant"]) == {"job_ring", "job_hd", "burst"}
    parsed = parse_prometheus(out.read_text())
    assert parsed[("strack_epochs_total", ())] == 2.0
    assert parsed[("strack_messages_total",
                   (("tenant", "burst"),))] == 2.0 * TENANTS[0].n_flows


def test_soak_rejects_events_backend():
    with pytest.raises(ValueError):
        soak(TOPO44, JOBS, TENANTS, epochs=1, net=NET,
             cfg=RunConfig(backend="events"))
