"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU, per assignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention as fa_raw
from repro.kernels.ssd_scan import ssd_scan as ssd_raw


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("B,H,K,Tq,Tk,hd", [
    (1, 4, 4, 128, 128, 64),       # MHA, single block
    (2, 8, 2, 256, 256, 64),       # GQA 4:1, multi-block
    (1, 4, 1, 128, 384, 128),      # MQA, rectangular
    (2, 2, 2, 100, 100, 32),       # ragged (non-multiple of block)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, H, K, Tq, Tk, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, H, Tq, hd), dtype)
    k = rand(ks[1], (B, K, Tk, hd), dtype)
    v = rand(ks[2], (B, K, Tk, hd), dtype)
    got = fa_raw(q, k, v, causal=True, block_q=128, block_k=128)
    want = kref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_non_causal():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (1, 2, 64, 64), jnp.float32)
    k = rand(ks[1], (1, 2, 192, 64), jnp.float32)
    v = rand(ks[2], (1, 2, 192, 64), jnp.float32)
    got = fa_raw(q, k, v, causal=False, block_q=64, block_k=64)
    want = kref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_sliding_window():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (1, 2, 256, 64), jnp.float32)
    k = rand(ks[1], (1, 2, 256, 64), jnp.float32)
    v = rand(ks[2], (1, 2, 256, 64), jnp.float32)
    got = fa_raw(q, k, v, causal=True, window=96, block_q=64, block_k=64)
    want = kref.flash_attention_ref(q, k, v, causal=True, window=96)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_decode_offset():
    """Decode: 1 query at absolute position q_offset against a long cache."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (2, 4, 1, 64), jnp.float32)
    k = rand(ks[1], (2, 2, 512, 64), jnp.float32)
    v = rand(ks[2], (2, 2, 512, 64), jnp.float32)
    got = fa_raw(q, k, v, causal=True, q_offset=300, block_q=1, block_k=128)
    want = kref.flash_attention_ref(q, k, v, causal=True, q_offset=300)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_ops_layout():
    """ops.py wrapper uses model layout (B, T, H, hd)."""
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = rand(ks[0], (2, 128, 4, 64), jnp.float32)
    k = rand(ks[1], (2, 128, 2, 64), jnp.float32)
    v = rand(ks[2], (2, 128, 2, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True)
    want = kref.flash_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------- #
# SSD scan
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("B,T,H,P,N,chunk", [
    (1, 128, 2, 32, 16, 32),
    (2, 256, 4, 64, 64, 128),
    (1, 64, 8, 16, 32, 64),     # chunk == T
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(B, T, H, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = rand(ks[0], (B, T, H, P), dtype)
    dt = jax.nn.softplus(rand(ks[1], (B, T, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    B_ = rand(ks[3], (B, T, N), dtype) / np.sqrt(N)
    C_ = rand(jax.random.PRNGKey(9), (B, T, N), dtype) / np.sqrt(N)
    got = ssd_raw(x, dt, A, B_, C_, chunk=chunk)
    want, _ = kref.ssd_ref(x, dt, A, B_, C_)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_ssd_matches_model_chunked_impl():
    """The model's pure-jnp ssd_chunked and the Pallas kernel must agree."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    B, T, H, P, N = 2, 128, 4, 32, 32
    x = rand(ks[0], (B, T, H, P), jnp.float32)
    dt = jax.nn.softplus(rand(ks[1], (B, T, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (H,), jnp.float32) * 0.3)
    B_ = rand(ks[3], (B, T, N), jnp.float32) / np.sqrt(N)
    C_ = rand(jax.random.PRNGKey(9), (B, T, N), jnp.float32) / np.sqrt(N)
    a = ssd_raw(x, dt, A, B_, C_, chunk=64)
    b, _ = ssd_chunked(x, dt, A, B_, C_, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-4)


def test_kernel_vs_model_attention():
    """Pallas flash attention vs the model's chunked JAX attention."""
    from repro.models.layers import _sdpa_chunked
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    B, T, H, K, hd = 2, 256, 8, 2, 64
    q = rand(ks[0], (B, T, H, hd), jnp.float32)
    k = rand(ks[1], (B, T, K, hd), jnp.float32)
    v = rand(ks[2], (B, T, K, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    a = ops.flash_attention(q, k, v, causal=True)
    b = _sdpa_chunked(q, k, v, pos, pos, True, None, 64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)
