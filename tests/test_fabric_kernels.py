"""Kernel-backend parity: the Pallas hot-path kernels vs the jnp stages.

The fabric's three hot stages (fused ring service+enqueue, the sort-free
enqueue ranker, the per-flow transitions) run either inline
(``kernel_backend="jnp"``) or as Pallas kernels
(``"pallas"``/``"pallas_interpret"``) built from the SAME stage cores —
see ``kernels/fabric_kernels.py``.  These tests pin the interpret-mode
path (the only one a CPU container can execute) bit-exact against the
jnp path per kernel and end-to-end:

  * the standalone ranker kernel against ``fabric._rank_in_queue`` and
    the O(M^2) lower-triangle oracle (the PR 6 contract: rank among
    flagged same-queue candidates in candidate order, -1 elsewhere),
  * the ``fused_stage_kernel`` wrapper's pytree/scalar/None round trip,
  * whole-program parity on a small permutation (warp + dense), a
    RoCEv2+PFC incast, and an active-set collective — the exact
    summaries must be BIT-equal, not band-equal,
  * knob validation and program-cache separation.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import fabric_kernels as fk
from repro.sim import fabric as F
from repro.sim.topology import FatTree
from repro.sim.workloads import (RunConfig, collective_scenario,
                                 incast_scenario, permutation_scenario,
                                 run)

pytestmark = [pytest.mark.tier1, pytest.mark.pallas]

SUMMARY_KEYS = ("max_fct", "avg_fct", "drops", "pauses", "unfinished",
                "max_collective_time")


def _assert_bit_equal(a: dict, b: dict, ctx=""):
    for k in SUMMARY_KEYS:
        assert a.get(k) == b.get(k), (ctx, k, a.get(k), b.get(k))


# ---------------------------------------------------------------------------
# Kernel 2: the ranker
# ---------------------------------------------------------------------------

def _rank_reference(qid: np.ndarray, flag: np.ndarray) -> np.ndarray:
    """O(M^2) lower-triangle oracle (same as tests/test_rank_active.py)."""
    m = qid.shape[0]
    ref = np.full(m, -1, np.int32)
    for i in range(m):
        if flag[i]:
            ref[i] = int(np.sum(flag[:i] & (qid[:i] == qid[i])))
    return ref


@pytest.mark.parametrize("m", [1, 3, 255, 256, 257, 700, 2600])
def test_ranker_kernel_matches_jnp_and_oracle(m):
    rng = np.random.default_rng(m)
    n_queues = 7
    qid = rng.integers(0, n_queues, size=m).astype(np.int32)
    flag = rng.random(m) < 0.6
    ref = _rank_reference(qid, flag)
    jnp_rank = np.asarray(F._rank_in_queue(jnp.asarray(qid),
                                           jnp.asarray(flag), n_queues))
    core_rank = np.asarray(fk.rank_in_queue_core(jnp.asarray(qid),
                                                 jnp.asarray(flag),
                                                 n_queues))
    kern_rank = np.asarray(fk.rank_in_queue_kernel(jnp.asarray(qid),
                                                   jnp.asarray(flag),
                                                   n_queues,
                                                   interpret=True))
    assert np.array_equal(jnp_rank, ref)
    assert np.array_equal(core_rank, ref)
    assert np.array_equal(kern_rank, ref)


def test_ranker_kernel_edge_cases():
    # none flagged, all flagged, one queue, empty
    for qid, flag, nq in [
            ([0, 1, 0, 1], [False] * 4, 2),
            ([3, 3, 3, 3], [True] * 4, 4),
            ([0], [True], 1),
            ([], [], 4)]:
        qid = np.asarray(qid, np.int32)
        flag = np.asarray(flag, bool)
        ref = _rank_reference(qid, flag)
        got = np.asarray(fk.rank_in_queue_kernel(
            jnp.asarray(qid.reshape(-1)), jnp.asarray(flag.reshape(-1)),
            nq, interpret=True))
        assert np.array_equal(got, ref), (qid, flag, got, ref)


def test_ranker_kernel_chunk_boundary_order():
    # candidates of one queue spanning a chunk boundary must keep global
    # candidate-index order across blocks (the carried count table)
    m = fk.RANK_CHUNK * 2 + 5
    qid = np.zeros(m, np.int32)
    flag = np.ones(m, bool)
    got = np.asarray(fk.rank_in_queue_kernel(jnp.asarray(qid),
                                             jnp.asarray(flag), 1,
                                             interpret=True))
    assert np.array_equal(got, np.arange(m, dtype=np.int32))


# ---------------------------------------------------------------------------
# The fused-stage wrapper
# ---------------------------------------------------------------------------

def test_fused_stage_kernel_round_trip():
    """Pytrees, traced scalars, None args and scalar outputs all survive
    the ref round trip, inside jit."""
    def core(tree, scale, nothing, t):
        assert nothing is None
        s = tree["a"] * scale + tree["b"]
        return {"out": s}, jnp.sum(s), t + 1

    args = ({"a": jnp.arange(4.0), "b": jnp.ones((4,))},
            jnp.float32(2.0), None, jnp.int32(7))
    direct = core(*args)
    via = jax.jit(lambda a: fk.fused_stage_kernel(core, a,
                                                  interpret=True))(args)
    assert np.array_equal(np.asarray(direct[0]["out"]),
                          np.asarray(via[0]["out"]))
    assert np.asarray(direct[1]) == np.asarray(via[1])
    assert np.asarray(direct[2]) == np.asarray(via[2])


# ---------------------------------------------------------------------------
# Whole-program parity, one scenario per kernel-heavy regime
# ---------------------------------------------------------------------------

def _topo():
    return FatTree(n_tor=4, hosts_per_tor=4, n_spine=4)


def test_perm_strack_parity_warp_and_dense():
    sc = permutation_scenario(_topo(), msg_bytes=64e3, seed=0)
    kw = dict(backend="fabric", n_ticks=4000, protocol="strack")
    for warp in (True, False):
        a = run(sc, RunConfig(**kw, time_warp=warp))
        b = run(sc, RunConfig(**kw, time_warp=warp,
                              kernel_backend="pallas_interpret"))
        _assert_bit_equal(a, b, f"perm warp={warp}")


def test_incast_roce_pfc_parity():
    sc = incast_scenario(_topo(), fan_in=8, msg_bytes=32e3, seed=1)
    kw = dict(backend="fabric", n_ticks=6000, protocol="rocev2",
              pfc=True)
    a = run(sc, RunConfig(**kw))
    b = run(sc, RunConfig(**kw, kernel_backend="pallas_interpret"))
    _assert_bit_equal(a, b, "incast roce+pfc")


def test_active_set_collective_parity():
    # dependency-gated ring allreduce keeps < active_cap flows live, so
    # this drives the gathered active-set transition kernel
    sc = collective_scenario(_topo(), "ring", 1, 8, 32e3)
    kw = dict(backend="fabric", n_ticks=20000, protocol="strack",
              active_cap=12)
    a = run(sc, RunConfig(**kw))
    b = run(sc, RunConfig(**kw, kernel_backend="pallas_interpret"))
    _assert_bit_equal(a, b, "active collective")
    # and the active-set kernel path matches the dense jnp program
    c = run(sc, RunConfig(backend="fabric", n_ticks=20000,
                          protocol="strack"))
    _assert_bit_equal(b, c, "active kernels vs dense jnp")


# ---------------------------------------------------------------------------
# Knob validation + program-cache separation
# ---------------------------------------------------------------------------

def test_unknown_kernel_backend_rejected():
    with pytest.raises(ValueError, match="kernel_backend"):
        RunConfig(backend="fabric", kernel_backend="cuda")
    with pytest.raises(ValueError, match="kernel_backend"):
        F._make_program(
            _topo(), 4, 100,
            F.FabricConfig(kernel_backend="nope"), F._trivial_dep(range(4)))


def test_kernel_backend_excludes_shard():
    with pytest.raises(ValueError, match="shard"):
        RunConfig(backend="fabric", kernel_backend="pallas_interpret",
                  shard=2)


def test_kernel_backend_separates_program_cache():
    F.clear_program_cache()
    sc = permutation_scenario(_topo(), msg_bytes=16e3, seed=0)
    kw = dict(backend="fabric", n_ticks=1500, protocol="strack")
    builds0 = F.program_builds
    run(sc, RunConfig(**kw))
    assert F.program_builds == builds0 + 1
    run(sc, RunConfig(**kw, kernel_backend="pallas_interpret"))
    assert F.program_builds == builds0 + 2     # distinct cache entry
    run(sc, RunConfig(**kw, kernel_backend="pallas_interpret"))
    assert F.program_builds == builds0 + 2     # ... that is then reused
