"""Dependency-scheduled collectives on the jitted fabric vs the
TraceRunner oracle (the acceptance gate for the unified experiment API),
plus unit tests of the new machinery: message->sub-flow striping entropy,
dependency-aware tick budgeting, the run()/sweep() contract and the
sweep() structure validation.

Parity band: since the per-hop latency pipeline, the fabric accrues
serialization + propagation at every traversed queue stage and returns
ACKs over the flow's real reverse path — the same delay model the oracle
integrates — so the per-handoff base RTT agrees between the backends and
chained collectives no longer accumulate a per-step constant error.  The
residual band covers tick quantisation and the deterministic-vs-rng ECN
dither (measured ratios across the algorithm matrix: 0.87-0.99).  Tests
run at 100 Gbps with serialisation-dominated chunks to keep the band
meaningful.
"""
import numpy as np
import pytest

from repro.core.params import NetworkSpec
from repro.sim.fabric import FabricConfig, _flow_arrays, expand_messages
from repro.sim.topology import full_bisection
from repro.sim.workloads import (Message, RunConfig, Scenario,
                                 collective_scenario, permutation_scenario,
                                 run, sweep)

pytestmark = pytest.mark.tier1

NET = NetworkSpec(link_gbps=100.0)
TOPO = full_bisection(2, 4)          # 8 hosts, 2 ToRs, 4 spines

# Collective completion times must agree within this factor.  The
# per-hop latency pipeline tightened this from the pre-PR-5 (0.5, 1.6)
# order-of-magnitude band (the folded-RTT model accumulated one constant
# of error per dependency handoff) to a real conformance gate — strictly
# narrower than the old single-shot FCT band (0.6, 1.6) too.
COLL_TOL = (0.75, 1.25)


def _both(sc, **cfg_kw):
    fb = run(sc, RunConfig(backend="fabric", **cfg_kw))
    ev = run(sc, RunConfig(backend="events", until=1e7, **cfg_kw))
    return fb, ev


def _assert_parity(fb, ev):
    assert fb["finished_groups"] == fb["total_groups"], fb
    assert ev["finished_groups"] == ev["total_groups"], ev
    r = fb["max_collective_time"] / ev["max_collective_time"]
    assert COLL_TOL[0] < r < COLL_TOL[1], (fb["max_collective_time"],
                                           ev["max_collective_time"])


# --------------------------------------------------------------------------- #
# acceptance: ring allreduce >=8 ranks, chunked, BOTH protocols, via run()
# --------------------------------------------------------------------------- #

@pytest.fixture(scope="module")
def ring_sc():
    """Ring allreduce, 8 ranks, 512KB, 2 chunks per 64KB segment."""
    return collective_scenario(TOPO, "ring", 1, 8, 512 * 2 ** 10, net=NET,
                               seed=0, chunk=32 * 2 ** 10)


def test_ring_allreduce_strack_fabric_matches_oracle(ring_sc):
    """STrack adaptive spray: the chunked ring trace completes on the
    jitted fabric with the oracle-parity collective time."""
    assert ring_sc.has_deps and len(ring_sc.messages) == 224
    fb, ev = _both(ring_sc, protocol="strack")
    assert fb["backend"] == "fabric" and ev["backend"] == "events"
    _assert_parity(fb, ev)


def test_ring_allreduce_roce4_fabric_matches_oracle(ring_sc):
    """4-QP striped RoCEv2 (the paper's tuned baseline, previously
    event-backend-only) runs the same trace on the fast path."""
    fb, ev = _both(ring_sc, protocol="rocev2", subflows=4)
    _assert_parity(fb, ev)
    assert fb["drops"] == 0 and ev["drops"] == 0  # PFC lossless


# --------------------------------------------------------------------------- #
# parity bands across the algorithm matrix (small n, multi-job)
# --------------------------------------------------------------------------- #

@pytest.mark.parametrize("algo,kw", [
    ("dbt", {}),
    ("hd", {}),
    ("a2a", dict(window=2)),
])
def test_collective_parity_vs_oracle(algo, kw):
    sc = collective_scenario(TOPO, algo, 2, 4, 256 * 2 ** 10, net=NET,
                             seed=0, chunk=128 * 2 ** 10, **kw)
    fb, ev = _both(sc, protocol="strack")
    _assert_parity(fb, ev)
    assert set(fb["group_fct"]) == set(ev["group_fct"]) == {0, 1}


def test_group_completion_ordering_matches_oracle():
    """Two ring jobs with 4x different payloads: both backends must finish
    the small group first — identical group completion ordering."""
    from repro.collective.algorithms import ring_allreduce
    msgs = []
    for g, (bytes_, hosts) in enumerate([(128 * 2 ** 10, (0, 1, 2, 3)),
                                         (512 * 2 ** 10, (4, 5, 6, 7))]):
        sub = ring_allreduce(4, bytes_, group=g, chunk=64 * 2 ** 10)
        base = len(msgs)
        for m in sub:
            msgs.append(Message(mid=m.mid + base, src=hosts[m.src],
                                dst=hosts[m.dst], size=m.size,
                                deps=tuple(d + base for d in m.deps),
                                group=g))
    sc = Scenario(name="ring_asym", topo=TOPO, net=NET,
                  messages=tuple(msgs))
    fb, ev = _both(sc, protocol="strack")
    _assert_parity(fb, ev)
    order_fb = sorted(fb["group_fct"], key=fb["group_fct"].get)
    order_ev = sorted(ev["group_fct"], key=ev["group_fct"].get)
    assert order_fb == order_ev == [0, 1]


# --------------------------------------------------------------------------- #
# unit: striping entropy, dependency-aware tick budget, sweep validation
# --------------------------------------------------------------------------- #

def test_striping_covers_multiple_entropies_per_message():
    """4-QP striping must give each message >=2 distinct path entropies
    (one QP each) — otherwise the stripes collapse onto one ECMP path."""
    sc = permutation_scenario(TOPO, 256 * 2 ** 10, net=NET, seed=0)
    cfg = FabricConfig(net=NET, protocol="rocev2", subflows=4)
    flows, dep = expand_messages(sc.messages, cfg.subflows)
    assert len(flows) == 4 * len(sc.messages)
    _, _, _, _, ent0 = _flow_arrays(flows, cfg)
    ent0, mof = np.asarray(ent0), np.asarray(dep.msg_of_flow)
    for i in range(dep.n_msgs):
        assert len(set(ent0[mof == i].tolist())) >= 2, i
    # seed-replayed entropies (oracle alignment) stay distinct too
    _, _, _, _, ent1 = _flow_arrays(
        flows, FabricConfig(net=NET, protocol="rocev2", subflows=4,
                            roce_entropy_seed=1234))
    ent1 = np.asarray(ent1)
    for i in range(dep.n_msgs):
        assert len(set(ent1[mof == i].tolist())) >= 2, i


def test_default_ticks_accounts_for_dependency_depth():
    """A chained trace must get a larger tick budget than the same flows
    without deps: the critical path serialises end-to-end."""
    hosts = [0, 4, 1, 5, 2, 6, 3, 7]  # cross-ToR chain, cycled
    size = 64 * 2 ** 10
    depth = 40
    chain = tuple(Message(mid=i, src=hosts[i % 8], dst=hosts[(i + 1) % 8],
                          size=size, deps=(i - 1,) if i else ())
                  for i in range(depth))
    flat = tuple(Message(mid=i, src=m.src, dst=m.dst, size=m.size)
                 for i, m in enumerate(chain))
    chained = Scenario("chain", TOPO, NET, chain)
    independent = Scenario("flat", TOPO, NET, flat)
    assert chained.default_ticks() > 2 * independent.default_ticks()
    # and the budget actually suffices: the chain completes end-to-end
    res = run(chained, RunConfig(backend="fabric"))
    assert res["unfinished"] == 0


def test_sweep_rejects_mismatching_scenarios():
    sc0 = permutation_scenario(TOPO, 64 * 2 ** 10, net=NET, seed=0)
    other_topo = permutation_scenario(full_bisection(4, 4), 64 * 2 ** 10,
                                      net=NET, seed=1)
    with pytest.raises(ValueError, match="topo"):
        sweep([sc0, other_topo], RunConfig())
    other_net = permutation_scenario(TOPO, 64 * 2 ** 10,
                                     net=NetworkSpec(link_gbps=400.0),
                                     seed=1)
    with pytest.raises(ValueError, match="net"):
        sweep([sc0, other_net], RunConfig())
    fewer = Scenario("fewer", TOPO, NET, sc0.messages[:-1])
    with pytest.raises(ValueError, match="messages"):
        sweep([sc0, fewer], RunConfig())
    with pytest.raises(ValueError, match="at least one"):
        sweep([], RunConfig())


def test_sweep_collectives_on_fabric():
    """Seed sweep of one collective placement structure: one vmapped jit,
    per-seed group completions."""
    scs = [collective_scenario(TOPO, "hd", 1, 4, 128 * 2 ** 10, net=NET,
                               seed=s, chunk=128 * 2 ** 10)
           for s in range(2)]
    rows = sweep(scs, RunConfig(backend="fabric", protocol="strack"))
    assert len(rows) == 2
    for r in rows:
        assert r["backend"] == "fabric"
        assert r["finished_groups"] == r["total_groups"] == 1


def test_run_config_validation():
    sc = permutation_scenario(TOPO, 64 * 2 ** 10, net=NET)
    with pytest.raises(ValueError, match="backend"):
        RunConfig(backend="quantum")
    with pytest.raises(ValueError, match="protocol"):
        RunConfig(protocol="tcp")
    with pytest.raises(ValueError, match="ack_path"):
        RunConfig(ack_path="telepathy")
    with pytest.raises(ValueError, match="fixed"):
        run(sc, RunConfig(backend="events", lb_mode="fixed"))
