"""Unit tests for the `make bench` parity gate: the BENCH_fabric.json
schema checker must flag parity failures, malformed reports and warp
throughput regressions with a non-zero exit, not bury them in a report
nobody reads."""
import copy
import json

import pytest

from benchmarks.perf import (check_report_file, regression_problems,
                             validate_report)

GOOD = {
    "meta": {"utc": "2026-07-31T00:00:00Z", "jax": "0.4.35",
             "backend": "cpu", "platform": "Linux"},
    "scenarios": {
        "perm1024": {
            "n_ticks": 9000, "n_hosts": 1024, "n_msgs": 1024,
            "dense": {"cold_s": 10.0, "run_s": 8.0, "compile_s": 2.0,
                      "ticks_per_s": 1125.0, "program_builds": 1},
            "warp": {"cold_s": 3.0, "run_s": 0.5, "compile_s": 2.5,
                     "ticks_per_s": 18000.0, "warp_trips": 1234,
                     "program_builds": 1},
            "speedup": 16.0, "parity_ok": True, "unfinished": 0,
            "max_fct_us": 700.5, "program_builds_total": 2,
            "kernels": {
                "pallas_interpret": {
                    "cold_s": 3.2, "run_s": 0.55, "compile_s": 2.65,
                    "ticks_per_s": 16363.6, "warp_trips": 1234,
                    "program_builds": 1, "parity_exact": True},
            },
        },
        "perm8k": {
            "n_ticks": 4452, "n_hosts": 8192, "n_msgs": 8192,
            "warp": {"cold_s": 27.0, "run_s": 20.0, "compile_s": 7.0,
                     "ticks_per_s": 216.0, "warp_trips": 113,
                     "program_builds": 1},
            "warp_only": True, "parity_ok": True, "unfinished": 0,
            "max_fct_us": 11.06, "program_builds_total": 1,
            "parity_spotcheck": {"n_hosts": 16, "n_msgs": 16,
                                 "fabric_us": 9.99, "events_us": 9.88,
                                 "ratio": 1.011, "ok": True},
        },
    },
    "scale_axis": [
        {"n_hosts": 64, "n_ticks": 4452, "kernel_backend": "jnp",
         "ticks_per_s": 9000.0, "compile_s": 5.0, "program_builds": 1,
         "warp_trips": 100},
        {"n_hosts": 64, "n_ticks": 4452,
         "kernel_backend": "pallas_interpret", "ticks_per_s": 8800.0,
         "compile_s": 5.1, "program_builds": 1, "warp_trips": 100},
        {"n_hosts": 8192, "n_ticks": 4452, "kernel_backend": "jnp",
         "ticks_per_s": 216.0, "compile_s": 7.0, "program_builds": 1,
         "warp_trips": 113},
    ],
}


def test_valid_report_passes():
    assert validate_report(GOOD) == []


def test_scale_axis_is_optional():
    old_style = copy.deepcopy(GOOD)
    del old_style["scale_axis"]
    assert validate_report(old_style) == []


def test_parity_failure_is_flagged():
    bad = copy.deepcopy(GOOD)
    bad["scenarios"]["perm1024"]["parity_ok"] = False
    problems = validate_report(bad)
    assert any("parity_ok is FALSE" in p for p in problems)


def test_warp_only_rows_skip_dense_requirements():
    # perm8k has no dense leg or speedup and must still validate (above),
    # but a NON-warp_only row without them must be flagged
    bad = copy.deepcopy(GOOD)
    bad["scenarios"]["perm8k"]["warp_only"] = False
    problems = validate_report(bad)
    assert any("missing key 'dense'" in p for p in problems)
    assert any("missing key 'speedup'" in p for p in problems)


def test_schema_violations_are_flagged():
    # missing scenario key
    bad = copy.deepcopy(GOOD)
    del bad["scenarios"]["perm1024"]["speedup"]
    assert any("missing key 'speedup'" in p for p in validate_report(bad))
    # missing scenario-level program_builds_total (the whole-scenario
    # build-count diagnostic)
    bad = copy.deepcopy(GOOD)
    del bad["scenarios"]["perm1024"]["program_builds_total"]
    assert any("missing key 'program_builds_total'" in p
               for p in validate_report(bad))
    # missing per-mode program_builds (what the retrace-regression hook
    # actually reads — distinct from the scenario-level total)
    bad = copy.deepcopy(GOOD)
    del bad["scenarios"]["perm1024"]["warp"]["program_builds"]
    assert any("warp: missing key 'program_builds'" in p
               for p in validate_report(bad))
    # wrong type
    bad = copy.deepcopy(GOOD)
    bad["scenarios"]["perm1024"]["n_ticks"] = "9000"
    assert any("n_ticks" in p for p in validate_report(bad))
    # malformed scale-axis point
    bad = copy.deepcopy(GOOD)
    del bad["scale_axis"][0]["compile_s"]
    assert any("scale_axis[0]" in p for p in validate_report(bad))
    # scale-axis points must carry their kernel_backend tag
    bad = copy.deepcopy(GOOD)
    del bad["scale_axis"][1]["kernel_backend"]
    assert any("scale_axis[1]: missing key 'kernel_backend'" in p
               for p in validate_report(bad))
    bad = copy.deepcopy(GOOD)
    bad["scale_axis"] = []
    assert any("scale_axis" in p for p in validate_report(bad))
    # empty scenarios
    assert any("scenarios" in p
               for p in validate_report({"meta": GOOD["meta"],
                                         "scenarios": {}}))
    # not even a dict
    assert validate_report([1, 2, 3])


def test_kernel_rows_are_validated():
    """The kernels axis: optional, but present rows must be well-formed
    and bit-exact — parity_exact=False is a gate failure by itself."""
    # the fixture's kernels row validates (test_valid_report_passes), and
    # a jnp-only report without one still validates
    no_kernels = copy.deepcopy(GOOD)
    del no_kernels["scenarios"]["perm1024"]["kernels"]
    assert validate_report(no_kernels) == []
    # parity_exact=False fires the gate naming backend and scenario
    bad = copy.deepcopy(GOOD)
    bad["scenarios"]["perm1024"]["kernels"]["pallas_interpret"][
        "parity_exact"] = False
    problems = validate_report(bad)
    assert any("parity_exact is FALSE" in p
               and "perm1024.kernels.pallas_interpret" in p
               for p in problems)
    # missing timing / parity keys inside a kernel row are flagged
    bad = copy.deepcopy(GOOD)
    del bad["scenarios"]["perm1024"]["kernels"]["pallas_interpret"][
        "parity_exact"]
    assert any("kernels.pallas_interpret: missing key 'parity_exact'" in p
               for p in validate_report(bad))
    # an empty kernels object is malformed, not silently fine
    bad = copy.deepcopy(GOOD)
    bad["scenarios"]["perm1024"]["kernels"] = {}
    assert any("kernels" in p for p in validate_report(bad))


def test_regression_gate_ignores_kernel_rows():
    """The throughput gate reads scenarios.<name>.warp.ticks_per_s only;
    a kernel-backend slowdown (or a removed kernels row) never fires it."""
    new = copy.deepcopy(GOOD)
    new["scenarios"]["perm1024"]["kernels"]["pallas_interpret"][
        "ticks_per_s"] = 1.0
    assert regression_problems(new, GOOD) == []
    del new["scenarios"]["perm1024"]["kernels"]
    assert regression_problems(new, GOOD) == []


def test_regression_gate():
    new = copy.deepcopy(GOOD)
    # identical reports: no problems
    assert regression_problems(new, GOOD) == []
    # 10% drop: inside the 20% tolerance
    new["scenarios"]["perm1024"]["warp"]["ticks_per_s"] = 16200.0
    assert regression_problems(new, GOOD) == []
    # 50% drop: gate fires, message names the scenario
    new["scenarios"]["perm1024"]["warp"]["ticks_per_s"] = 9000.0
    problems = regression_problems(new, GOOD)
    assert len(problems) == 1 and "perm1024" in problems[0]
    # scenarios only on one side are skipped; absent baseline is a pass
    del new["scenarios"]["perm1024"]
    assert regression_problems(new, GOOD) == []
    assert regression_problems(GOOD, None) == []


def test_check_report_file_exit_codes(tmp_path):
    good = tmp_path / "good.json"
    good.write_text(json.dumps(GOOD))
    assert check_report_file(str(good)) == 0

    bad_dict = copy.deepcopy(GOOD)
    bad_dict["scenarios"]["perm1024"]["parity_ok"] = False
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(bad_dict))
    assert check_report_file(str(bad)) == 1

    broken = tmp_path / "broken.json"
    broken.write_text("{not json")
    assert check_report_file(str(broken)) == 2
    assert check_report_file(str(tmp_path / "absent.json")) == 2


def _patch_runners(monkeypatch, parity_ok=True):
    import benchmarks.perf as perf

    def fake_bench_scenario(name, sc, cfg_kw, repeats=2,
                            kernel_backends=()):
        row = copy.deepcopy(GOOD["scenarios"]["perm1024"])
        row["parity_ok"] = parity_ok
        return row

    monkeypatch.setattr(perf, "bench_scenario", fake_bench_scenario)
    monkeypatch.setattr(perf, "canonical_scenarios",
                        lambda: {"fake": (None, {})})
    monkeypatch.setattr(perf, "scale_scenarios", lambda: {})
    monkeypatch.setattr(perf, "bench_scale_axis",
                        lambda repeats=1, kernel_backends=():
                        copy.deepcopy(GOOD["scale_axis"]))
    return perf


def test_bench_all_exits_nonzero_on_parity_failure(monkeypatch, tmp_path):
    """bench_all must sys.exit(1) — not merely log — when a scenario's
    dense/warp parity gate fails."""
    perf = _patch_runners(monkeypatch, parity_ok=False)
    out = tmp_path / "BENCH_fabric.json"
    hist = tmp_path / "BENCH_history.jsonl"     # NOT the repo's trend file
    with pytest.raises(SystemExit) as exc:
        perf.bench_all(str(out), repeats=1, history_path=str(hist))
    assert exc.value.code == 1
    # the report is still written for post-mortem, then the gate fires
    assert json.loads(out.read_text())["scenarios"]["fake"]["parity_ok"] \
        is False


def test_bench_all_exits_nonzero_on_throughput_regression(monkeypatch,
                                                          tmp_path):
    """bench_all reads the committed report before overwriting and fails
    on a >20% warp ticks/sec drop at any shared scenario."""
    perf = _patch_runners(monkeypatch, parity_ok=True)
    out = tmp_path / "BENCH_fabric.json"
    hist = tmp_path / "BENCH_history.jsonl"     # NOT the repo's trend file
    baseline = {"scenarios": {"fake": {
        "warp": {"ticks_per_s":
                 GOOD["scenarios"]["perm1024"]["warp"]["ticks_per_s"]
                 * 10.0}}}}
    out.write_text(json.dumps(baseline))
    with pytest.raises(SystemExit) as exc:
        perf.bench_all(str(out), repeats=1, history_path=str(hist))
    assert exc.value.code == 1
    # a matching baseline passes (fresh report replaces it)
    out.write_text(json.dumps({"scenarios": {"fake": {
        "warp": {"ticks_per_s":
                 GOOD["scenarios"]["perm1024"]["warp"]["ticks_per_s"]}}}}))
    report = perf.bench_all(str(out), repeats=1, history_path=str(hist))
    assert report["scenarios"]["fake"]["parity_ok"] is True
