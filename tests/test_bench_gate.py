"""Unit tests for the `make bench` parity gate: the BENCH_fabric.json
schema checker must flag parity failures and malformed reports with a
non-zero exit, not bury them in a report nobody reads."""
import copy
import json

import pytest

from benchmarks.perf import check_report_file, validate_report

GOOD = {
    "meta": {"utc": "2026-07-31T00:00:00Z", "jax": "0.4.35",
             "backend": "cpu", "platform": "Linux"},
    "scenarios": {
        "perm1024": {
            "n_ticks": 9000, "n_hosts": 1024, "n_msgs": 1024,
            "dense": {"cold_s": 10.0, "run_s": 8.0, "compile_s": 2.0,
                      "ticks_per_s": 1125.0},
            "warp": {"cold_s": 3.0, "run_s": 0.5, "compile_s": 2.5,
                     "ticks_per_s": 18000.0, "warp_trips": 1234},
            "speedup": 16.0, "parity_ok": True, "unfinished": 0,
            "max_fct_us": 700.5,
        },
    },
}


def test_valid_report_passes():
    assert validate_report(GOOD) == []


def test_parity_failure_is_flagged():
    bad = copy.deepcopy(GOOD)
    bad["scenarios"]["perm1024"]["parity_ok"] = False
    problems = validate_report(bad)
    assert any("parity_ok is FALSE" in p for p in problems)


def test_schema_violations_are_flagged():
    # missing scenario key
    bad = copy.deepcopy(GOOD)
    del bad["scenarios"]["perm1024"]["speedup"]
    assert any("missing key 'speedup'" in p for p in validate_report(bad))
    # wrong type
    bad = copy.deepcopy(GOOD)
    bad["scenarios"]["perm1024"]["n_ticks"] = "9000"
    assert any("n_ticks" in p for p in validate_report(bad))
    # empty scenarios
    assert any("scenarios" in p
               for p in validate_report({"meta": GOOD["meta"],
                                         "scenarios": {}}))
    # not even a dict
    assert validate_report([1, 2, 3])


def test_check_report_file_exit_codes(tmp_path):
    good = tmp_path / "good.json"
    good.write_text(json.dumps(GOOD))
    assert check_report_file(str(good)) == 0

    bad_dict = copy.deepcopy(GOOD)
    bad_dict["scenarios"]["perm1024"]["parity_ok"] = False
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(bad_dict))
    assert check_report_file(str(bad)) == 1

    broken = tmp_path / "broken.json"
    broken.write_text("{not json")
    assert check_report_file(str(broken)) == 2
    assert check_report_file(str(tmp_path / "absent.json")) == 2


def test_bench_all_exits_nonzero_on_parity_failure(monkeypatch, tmp_path):
    """bench_all must sys.exit(1) — not merely log — when a scenario's
    dense/warp parity gate fails."""
    import benchmarks.perf as perf

    def fake_bench_scenario(name, sc, cfg_kw, repeats=2):
        row = copy.deepcopy(GOOD["scenarios"]["perm1024"])
        row["parity_ok"] = False
        return row

    monkeypatch.setattr(perf, "bench_scenario", fake_bench_scenario)
    monkeypatch.setattr(
        perf, "canonical_scenarios",
        lambda: {"fake": (None, {})})
    out = tmp_path / "BENCH_fabric.json"
    with pytest.raises(SystemExit) as exc:
        perf.bench_all(str(out), repeats=1)
    assert exc.value.code == 1
    # the report is still written for post-mortem, then the gate fires
    assert json.loads(out.read_text())["scenarios"]["fake"]["parity_ok"] \
        is False
