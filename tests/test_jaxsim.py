"""Jitted time-stepped simulator: the paper's incast claims (Figs 16-20)."""
import numpy as np
import pytest

from repro.sim.jaxsim import IncastConfig, run_incast

# 25k dense ticks with a per-tick trace: excluded from `make test-fast`
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def incast8():
    cfg = IncastConfig(n_flows=8, msg_bytes=4 * 2 ** 20)
    return run_incast(cfg, n_ticks=25000)


def test_all_flows_complete_under_drops(incast8):
    final, m = incast8
    done = np.asarray(m["done"])
    assert done[-1] == 8
    assert np.asarray(m["drops"])[-1] > 0   # lossy first RTT...


def test_drops_confined_to_startup(incast8):
    """Fig 16: STrack only drops in the first RTT(s)."""
    final, m = incast8
    drops = np.asarray(m["drops"])
    assert drops[300] == drops[-1], "drops continued past startup"


def test_queue_stabilises_at_target(incast8):
    """Fig 20: steady-state queue ~= target queuing delay."""
    final, m = incast8
    q = np.asarray(m["queue_pkts"]).astype(float)
    done = np.asarray(m["done"])
    busy = np.nonzero(done < 8)[0]
    steady = q[busy[len(busy) // 2]: busy[-1]]
    target = m["target_qdelay_pkts"]
    med = np.median(steady)
    assert 0.5 * target < med < 2.0 * target, (med, target)


def test_fairness(incast8):
    """Fig 17: flows converge to fair shares (Jain index ~ 1)."""
    final, m = incast8
    d = np.asarray(m["delivered"])[-1]
    jain = d.sum() ** 2 / (len(d) * np.sum(d * d))
    assert jain > 0.98, jain


def test_link_fully_utilised(incast8):
    """Bottleneck should run at ~100% while flows are active."""
    final, m = incast8
    q = np.asarray(m["queue_pkts"])
    done = np.asarray(m["done"])
    busy = np.nonzero(done < 8)[0]
    mid = busy[len(busy) // 4: 3 * len(busy) // 4]
    # queue never empties mid-transfer = no starvation
    assert (q[mid] == 0).mean() < 0.02
