"""Hierarchical int8 inter-pod reduction: correctness + wire bytes."""
import os
import sys

import pytest

# needs >1 device: spawn a subprocess with a forced device count
import subprocess

SCRIPT = '''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.runtime.compress import (hierarchical_int8_psum,
                                    two_stage_allreduce_bytes_demo)

mesh = make_mesh((2, 4, 2), ("pod", "data", "model"))
x = jax.random.normal(jax.random.PRNGKey(0), (16, 64), jnp.float32)
xs = jax.device_put(x, jax.sharding.NamedSharding(mesh, P(("pod", "data"))))
got = jax.jit(lambda v: hierarchical_int8_psum(v, mesh))(xs)
from repro.compat import shard_map
want = jax.jit(shard_map(lambda v: jax.lax.psum(v, ("pod", "data")),
                         mesh=mesh, in_specs=P(("pod", "data")),
                         out_specs=P(("pod", "data")),
                         check_vma=False))(xs)
err = float(jnp.max(jnp.abs(got - want))) / float(jnp.max(jnp.abs(want)))
assert err < 0.02, err          # int8 quantisation error only

res = two_stage_allreduce_bytes_demo(mesh)
# the pod-crossing payload must be int8 (4x smaller than a f32 exchange)
f32_exchange = res["plain_f32"]["all-reduce"] / 7  # per-hop scale ref
int8_hop = res["hier_int8"]["collective-permute"]
assert int8_hop > 0
assert int8_hop < res["plain_f32"]["all-reduce"] / 2
print("OK", err, int8_hop)
'''


def test_hierarchical_int8_psum_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
