# Developer / CI entry points.  PYTHONPATH is prepended, not replaced.
PY      := python
PP      := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: tier1 fabric-smoke smoke benchmarks

# The tier-1 gate (same command as ROADMAP.md).
tier1:
	$(PP) $(PY) -m pytest -x -q

# 2k-tick jitted fabric run: perf canary for the lax.scan hot path.
fabric-smoke:
	$(PP) $(PY) -m benchmarks.fabric_smoke 2000

# What CI should run on every change.
smoke: tier1 fabric-smoke

# Full paper-figure benchmark sweep (slow).
benchmarks:
	$(PP) $(PY) -m benchmarks.run
