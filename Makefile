# Developer / CI entry points.  PYTHONPATH is prepended, not replaced.
PY      := python
PP      := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: tier1 fabric-smoke smoke benchmarks

# The tier-1 gate (same command as ROADMAP.md).
tier1:
	$(PP) $(PY) -m pytest -x -q

# 2k-tick jitted fabric runs (STrack + RoCEv2-on-fabric canary): perf and
# baseline-port regressions on the lax.scan hot path fail fast here.
fabric-smoke:
	$(PP) $(PY) -m benchmarks.fabric_smoke 2000 all

# What CI should run on every change.
smoke: tier1 fabric-smoke

# Full paper-figure benchmark sweep (slow).
benchmarks:
	$(PP) $(PY) -m benchmarks.run
