# Developer / CI entry points.  PYTHONPATH is prepended, not replaced.
PY      := python
PP      := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: tier1 test test-fast fabric-smoke collective-smoke bench-smoke \
	chaos-smoke scale-smoke smoke bench benchmarks update-golden profile \
	soak soak-smoke serve-metrics

# The tier-1 gate (same command as ROADMAP.md).
tier1:
	$(PP) $(PY) -m pytest -x -q

# Full suite: everything, fuzz at its full example count (pytest.ini
# registers the tier1 / slow / fuzz markers).
test:
	$(PP) $(PY) -m pytest -q

# Smoke-speed suite: slow-marked tests excluded and the differential fuzz
# suite reduced to 3 examples (full count under `make test` / tier1).
# The second pass re-runs the shard-marked tests under a FORCED 4-device
# host platform so multi-device shard_map parity never silently skips on
# single-device CI hosts (XLA_FLAGS must be set before jax imports, so it
# needs its own interpreter).
test-fast:
	$(PP) REPRO_FUZZ_EXAMPLES=3 $(PY) -m pytest -q -m "not slow"
	$(PP) REPRO_FUZZ_EXAMPLES=3 \
	  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	  $(PY) -m pytest -q -m shard

# Regenerate tests/golden/*.json after an INTENTIONAL fidelity change;
# review the diff like code.
update-golden:
	$(PP) $(PY) -m pytest tests/test_golden.py --update-golden -q

# 2k-tick jitted fabric runs (STrack + RoCEv2-on-fabric canary): perf and
# baseline-port regressions on the lax.scan hot path fail fast here.
fabric-smoke:
	$(PP) $(PY) -m benchmarks.fabric_smoke 2000 all

# 2k-tick dependency-scheduled collective on the fabric (ring allreduce,
# strack + rocev2 + 4-QP striped rocev2): gating/striping regressions on
# the unified run(scenario, cfg) path fail fast here.
collective-smoke:
	$(PP) $(PY) -m benchmarks.collectives --backend fabric --smoke

# 2k-tick perf canary: warm time-warped fabric must beat a ticks/sec
# floor and agree exactly with dense ticking (see docs/performance.md).
bench-smoke:
	$(PP) $(PY) -m benchmarks.perf --smoke

# Chaos-path gates (benchmarks/oversub_linkdown.py --chaos-smoke):
# the degenerate t=0 flap schedule must reproduce native dead-link
# results bit-exactly, a mid-run flap must drain with recovery-counter
# activity, and a clean+flapped chaos soak must compile ONE program.
chaos-smoke:
	$(PP) $(PY) -m benchmarks.oversub_linkdown --chaos-smoke

# What CI should run on every change.
smoke: tier1 fabric-smoke collective-smoke bench-smoke chaos-smoke

# 512-host warp smoke point: a midsize permutation must clear a warm
# ticks/sec floor, catching at-scale scan regressions the 16-host
# bench-smoke canary can't see.
scale-smoke:
	$(PP) $(PY) -m benchmarks.perf --scale

# Perf trajectory: dense vs event-horizon wall-clock + ticks/sec on the
# canonical scenarios (1024-host permutation, chunked ring, incast-256),
# the warp-only 8K scenarios (perm8k, allreduce8k) and the n_hosts scale
# axis; writes BENCH_fabric.json.  Runs the 512-host scale smoke first,
# then exits non-zero when any scenario's parity gate fails, the JSON
# violates the schema, or warp ticks/sec regressed >20% against the
# previously committed report (benchmarks/perf.py validate_report /
# regression_problems; re-check with --check).
bench: scale-smoke
	$(PP) $(PY) -m benchmarks.perf --out BENCH_fabric.json

# Trace one warm warp scenario (perm1024) under jax.profiler.trace into
# traces/fabric: compile happens outside the trace, so the profile shows
# the scan body the Pallas kernels target.  View with
# `tensorboard --logdir traces/fabric`.  Override the scenario or the
# kernel backend via benchmarks.perf --profile* / --kernel-backends.
profile:
	$(PP) $(PY) -m benchmarks.perf --profile traces/fabric

# Full paper-figure benchmark sweep (slow).
benchmarks:
	$(PP) $(PY) -m benchmarks.run

# Observatory soak: 64-host mixed workload (2 training jobs + an
# inference burst tenant) for 10 warp epochs, counters carried across
# epochs; writes BENCH_soak.prom (Prometheus text exposition) and gates
# on drain, one-program reuse, exposition round-trip and the per-tenant
# FCT spot check vs the events oracle (benchmarks/soak.py, docs/
# observatory.md).
soak:
	$(PP) $(PY) -m benchmarks.soak --out BENCH_soak.prom

# CI-sized soak: small fleet, 3 epochs of 2000 ticks, same gates.
soak-smoke:
	$(PP) $(PY) -m benchmarks.soak --smoke --out BENCH_soak.prom

# Serve the soak's metrics file on http://127.0.0.1:9109/metrics
# (re-read per scrape, so a running soak shows up live).
serve-metrics:
	$(PP) $(PY) -m repro.obs.exporter --file BENCH_soak.prom --port 9109
