# Developer / CI entry points.  PYTHONPATH is prepended, not replaced.
PY      := python
PP      := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: tier1 fabric-smoke collective-smoke smoke benchmarks

# The tier-1 gate (same command as ROADMAP.md).
tier1:
	$(PP) $(PY) -m pytest -x -q

# 2k-tick jitted fabric runs (STrack + RoCEv2-on-fabric canary): perf and
# baseline-port regressions on the lax.scan hot path fail fast here.
fabric-smoke:
	$(PP) $(PY) -m benchmarks.fabric_smoke 2000 all

# 2k-tick dependency-scheduled collective on the fabric (ring allreduce,
# strack + rocev2 + 4-QP striped rocev2): gating/striping regressions on
# the unified run(scenario, cfg) path fail fast here.
collective-smoke:
	$(PP) $(PY) -m benchmarks.collectives --backend fabric --smoke

# What CI should run on every change.
smoke: tier1 fabric-smoke collective-smoke

# Full paper-figure benchmark sweep (slow).
benchmarks:
	$(PP) $(PY) -m benchmarks.run
