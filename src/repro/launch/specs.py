"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

No device allocation — the dry-run lowers against these. Modality frontends
are stubs per the assignment: ``vis_embed`` / ``frames`` are precomputed
embedding tensors."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES
from ..models import lm
from ..models.config import ModelConfig
from ..runtime.optimizer import OptConfig, init_opt


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs_for(cfg: ModelConfig, shape_name: str) -> dict:
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    mode = info["mode"]
    if mode == "decode":
        batch = {"tokens": sds((B, 1), jnp.int32)}
    else:
        batch = {"tokens": sds((B, S), jnp.int32)}
        if mode == "train":
            batch["labels"] = sds((B, S), jnp.int32)
    if cfg.kind == "vlm" and mode != "decode":
        batch["vis_embed"] = sds((B, cfg.n_vis_tokens, cfg.d_model),
                                 jnp.float32)
    if cfg.kind == "encdec" and mode != "decode":
        batch["frames"] = sds((B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


def param_specs_for(cfg: ModelConfig, dtype=None):
    shapes = jax.eval_shape(
        lambda k: lm.init_params(k, cfg), jax.random.PRNGKey(0))
    if dtype is not None:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, dtype), shapes)
    return shapes


def opt_specs_for(cfg: ModelConfig, opt_cfg: OptConfig):
    p = param_specs_for(cfg)
    return jax.eval_shape(lambda q: init_opt(q, opt_cfg), p)


def cache_specs_for(cfg: ModelConfig, shape_name: str):
    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    return jax.eval_shape(lambda: lm.init_cache(cfg, B, S))


def decode_extra_specs(cfg: ModelConfig, shape_name: str):
    info = SHAPES[shape_name]
    return {"tokens": sds((info["batch"], 1), jnp.int32),
            "pos": sds((), jnp.int32)}
