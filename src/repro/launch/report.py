"""Render EXPERIMENTS.md tables from cached dry-run JSONs."""
from __future__ import annotations

import glob
import json
import os


def load_cells(mesh: str = "pod", tag: str = ""):
    cells = []
    for fn in sorted(glob.glob(f"experiments/dryrun/*__{mesh}{tag}.json")):
        base = os.path.basename(fn)
        # untagged cells end exactly with __<mesh>.json (arch names may
        # contain dots, e.g. mamba2-2.7b)
        if tag == "" and not base.endswith(f"__{mesh}.json"):
            continue
        cells.append(json.load(open(fn)))
    return cells


def fmt_bytes(b):
    return f"{b/2**30:.2f}GiB" if b > 2**29 else f"{b/2**20:.0f}MiB"


def roofline_table(mesh: str = "pod", tag: str = "") -> str:
    rows = ["| arch | shape | compute | memory | collective | bound | "
            "6ND/HLO | roofline frac |",
            "|---|---|---|---|---|---|---|---|"]
    for c in load_cells(mesh, tag):
        r = c["roofline"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_s']*1e3:.1f}ms "
            f"| {r['memory_s']*1e3:.1f}ms | {r['collective_s']*1e3:.1f}ms "
            f"| {r['dominant'].replace('_s','')} "
            f"| {r['model_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def dryrun_table(mesh: str = "pod", tag: str = "") -> str:
    rows = ["| arch | shape | chips | args/dev | temp/dev | compile | "
            "AR | AG | RS | A2A | CP |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for c in load_cells(mesh, tag):
        m = c["memory"]
        cb = c["roofline"]["collective_breakdown"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['n_chips']} "
            f"| {fmt_bytes(m['argument_bytes'])} "
            f"| {fmt_bytes(m['temp_bytes'])} | {c['compile_s']:.0f}s "
            f"| {cb['all-reduce']/1e9:.1f}GB | {cb['all-gather']/1e9:.1f}GB "
            f"| {cb['reduce-scatter']/1e9:.1f}GB "
            f"| {cb['all-to-all']/1e9:.1f}GB "
            f"| {cb['collective-permute']/1e9:.1f}GB |")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "pod"
    tag = sys.argv[2] if len(sys.argv) > 2 else ""
    print("## Roofline —", mesh, tag)
    print(roofline_table(mesh, tag))
    print()
    print("## Dry-run —", mesh, tag)
    print(dryrun_table(mesh, tag))
