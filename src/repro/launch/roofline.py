"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

  compute    = HLO_FLOPs_global / (chips * 197 TFLOP/s bf16)
  memory     = HLO_bytes_global / (chips * 819 GB/s HBM)
  collective = per-chip collective bytes / 50 GB/s per ICI link

``compiled.cost_analysis()`` reports the per-partition SPMD program, so
global = per-device * chips.  Collective bytes are NOT in cost_analysis:
we parse the optimized HLO and apply ring-algorithm byte counts
(all-reduce 2x result, all-gather 1x result, reduce-scatter (g-1)x result,
all-to-all 1x, collective-permute 1x).
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> float:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0.0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return float(b * n)


def _result_bytes(line: str, op: str) -> float:
    """Sum the result shapes (text between '=' and the op keyword).

    NB: the instruction NAME also contains the op string
    (``%all-reduce.3 = f32[..] all-reduce(..)``), so search after '='."""
    eq = line.find("=")
    if eq < 0:
        return 0.0
    k = line.find(f" {op}(", eq)
    if k < 0:
        return 0.0
    seg = line[eq + 1:k]
    return sum(_shape_bytes(m.group(1), m.group(2))
               for m in _SHAPE_RE.finditer(seg))


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def parse_collective_bytes(hlo_text: str, n_devices: int) -> dict:
    """Per-device bytes moved, by collective kind (ring formulas)."""
    out = {k: 0.0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        for op in COLLECTIVES:
            key = f" {op}("
            key_start = f" {op}-start("
            if key in line or key_start in line:
                opk = op + ("-start" if key_start in line else "")
                rb = _result_bytes(line, opk)
                g = _group_size(line, n_devices)
                if op == "all-reduce":
                    moved = 2.0 * rb * (g - 1) / max(g, 1)
                elif op == "all-gather":
                    moved = rb * (g - 1) / max(g, 1)
                elif op == "reduce-scatter":
                    moved = rb * (g - 1)
                elif op == "all-to-all":
                    moved = rb * (g - 1) / max(g, 1)
                else:  # collective-permute
                    moved = rb
                out[op] += moved
                counts[op] += 1
                break
    out["total"] = sum(out[k] for k in COLLECTIVES)
    out["counts"] = counts
    return out


def roofline(cost: dict, coll: dict, n_chips: int, model_flops: float,
             mode: str) -> dict:
    """cost: compiled.cost_analysis() (per-device). Returns the 3 terms."""
    dev_flops = float(cost.get("flops", 0.0))
    dev_bytes = float(cost.get("bytes accessed", 0.0))
    t_compute = dev_flops / PEAK_FLOPS
    t_memory = dev_bytes / HBM_BW
    t_coll = coll["total"] / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    hlo_flops_global = dev_flops * n_chips
    return {
        **terms,
        "dominant": dom,
        "hlo_flops_global": hlo_flops_global,
        "hlo_bytes_per_dev": dev_bytes,
        "collective_bytes_per_dev": coll["total"],
        "collective_breakdown": {k: coll[k] for k in COLLECTIVES},
        "collective_counts": coll["counts"],
        "model_flops": model_flops,
        "model_flops_ratio": (model_flops / hlo_flops_global
                              if hlo_flops_global else 0.0),
        "bound_time_s": max(terms.values()),
        "roofline_fraction": (
            # fraction of the bound step time spent at the compute roof
            t_compute / max(max(terms.values()), 1e-30)),
        # model-FLOPs utilisation: useful-work time / bound step time —
        # the headline §Perf score (insensitive to recompute waste)
        "mfu": (model_flops / (n_chips * PEAK_FLOPS))
        / max(max(terms.values()), 1e-30),
    }


def model_flops_for(cfg, shape_info) -> float:
    """6·N·D (train) / 2·N·D (inference), N = active params, D = tokens."""
    B, S = shape_info["batch"], shape_info["seq"]
    mode = shape_info["mode"]
    n_active = cfg.active_param_count()
    if mode == "train":
        return 6.0 * n_active * B * S
    if mode == "prefill":
        return 2.0 * n_active * B * S
    return 2.0 * n_active * B * 1  # decode: one token per sequence
