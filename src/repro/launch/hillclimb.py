import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# §Perf hillclimbing driver: run one (arch x shape) cell under a named
# variant, record the three roofline terms to experiments/perf/<cell>.jsonl,
# and print the before/after delta of the dominant term.
#
#   PYTHONPATH=src python -m repro.launch.hillclimb \
#       --arch llama3-8b --shape train_4k --variant bf16_stream
import argparse
import dataclasses
import json

from ..configs import get_config
from .dryrun import run_cell

# Named variants: each returns kwargs for run_cell (+ cfg transform).
VARIANTS = {
    # paper-faithful baseline: TP(model) x FSDP(data), fp32 master params,
    # fp32 residual stream psums, chunked attention at 512
    "baseline": dict(),
    # H: bf16 residual stream -> TP all-reduces halve
    "bf16_stream": dict(cfg=dict(dtype="bfloat16")),
    # H: larger attention KV chunk -> fewer online-softmax carry sweeps
    "attn_chunk_2048": dict(cfg=dict(attn_chunk=2048)),
    "attn_chunk_4096": dict(cfg=dict(attn_chunk=4096)),
    # H: no TP — pure DP over all 256/512 chips with ZeRO-3 weight sharding
    "ddp_zero3": dict(layout="ddp"),
    # H: save-dots remat (less recompute, more temp memory)
    "remat_dots": dict(cfg=dict(remat="dots")),
    "remat_none": dict(cfg=dict(remat="none")),
    # H: int8 gradient compression w/ error feedback (inter-pod DCN lever)
    "grad_compress": dict(grad_compress=True),
    # H: bf16 norms -> the TP all-reduce is not hoisted into f32
    "bf16_norms": dict(cfg=dict(norm_f32=False)),
    # H: bf16 online-softmax state -> chunked-attention carry bytes halve
    "attn_bf16": dict(cfg=dict(attn_f32=False)),
    # H: flash-style backward — recompute p per kv chunk instead of saving
    # the (T x S) f32 probabilities across the scan
    "attn_remat": dict(cfg=dict(attn_remat_chunk=True)),
    # H: naive attention (one materialised p, fewer copies than scan saves)
    "attn_naive": dict(cfg=dict(attn_impl="naive")),
    "combo_ddp_attnremat": dict(layout="ddp",
                                cfg=dict(attn_remat_chunk=True)),
    "combo_ddp_attnremat_comp": dict(layout="ddp", grad_compress=True,
                                     cfg=dict(attn_remat_chunk=True)),
    "combo_ddp_attnremat_dots": dict(layout="ddp",
                                     cfg=dict(attn_remat_chunk=True,
                                              remat="dots")),
    # H: Megatron sequence parallelism (TP AR -> RS+AG, half the bytes)
    "seqpar": dict(cfg=dict(seq_shard=True)),
    "combo_seqpar_attnremat": dict(cfg=dict(seq_shard=True,
                                            attn_remat_chunk=True)),
    # H: bf16 master params halve the FSDP weight-gather bytes
    "params_bf16": dict(params_bf16=True),
    "combo_bf16params_attnremat": dict(params_bf16=True,
                                       cfg=dict(attn_remat_chunk=True)),
    "combo_final": dict(layout="ddp", params_bf16=True,
                        cfg=dict(attn_remat_chunk=True, remat="dots")),
    # combos
    "combo_bf16_chunk": dict(cfg=dict(dtype="bfloat16", attn_chunk=2048)),
    "combo_norm_attn": dict(cfg=dict(norm_f32=False, attn_f32=False)),
    "combo_ddp_norm_attn": dict(layout="ddp",
                                cfg=dict(norm_f32=False, attn_f32=False)),
    "combo_ddp_norm_attn_comp": dict(layout="ddp", grad_compress=True,
                                     cfg=dict(norm_f32=False,
                                              attn_f32=False)),
    "combo_ddp_bf16": dict(layout="ddp", cfg=dict(dtype="bfloat16")),
    "combo_ddp_bf16_chunk": dict(layout="ddp",
                                 cfg=dict(dtype="bfloat16",
                                          attn_chunk=2048)),
    "combo_ddp_bf16_compress": dict(layout="ddp", grad_compress=True,
                                    cfg=dict(dtype="bfloat16")),
    # MoE-specific: smaller dispatch groups (dispatch FLOPs ~ group size)
    "moe_group_256": dict(cfg=dict(moe_group=256)),
    "moe_group_128": dict(cfg=dict(moe_group=128)),
    "combo_ddp_attnremat_moe128": dict(layout="ddp",
                                       cfg=dict(attn_remat_chunk=True,
                                                moe_group=128)),
}


def run_variant(arch: str, shape: str, mesh: str, variant: str,
                micro_batches: int = 8):
    spec = VARIANTS[variant]
    cfg = get_config(arch)
    if spec.get("cfg"):
        cfg = dataclasses.replace(cfg, **spec["cfg"])
    rec = run_cell(arch, shape, mesh,
                   micro_batches=micro_batches,
                   grad_compress=spec.get("grad_compress", False),
                   layout=spec.get("layout", "2d"),
                   params_bf16=spec.get("params_bf16", False),
                   cfg_override=cfg, save=False, probes=True)
    rec["variant"] = variant
    os.makedirs("experiments/perf", exist_ok=True)
    out = f"experiments/perf/{arch}__{shape}__{mesh}.jsonl"
    with open(out, "a") as f:
        f.write(json.dumps(rec) + "\n")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--variant", required=True, nargs="+")
    ap.add_argument("--micro-batches", type=int, default=8)
    args = ap.parse_args()
    for v in args.variant:
        try:
            rec = run_variant(args.arch, args.shape, args.mesh, v,
                              args.micro_batches)
            r = rec["roofline"]
            print(f"[perf] {args.arch}x{args.shape}x{args.mesh} {v}: "
                  f"compute={r['compute_s']*1e3:.0f}ms "
                  f"mem={r['memory_s']*1e3:.0f}ms "
                  f"coll={r['collective_s']*1e3:.0f}ms "
                  f"bound={r['dominant']} "
                  f"frac={r['roofline_fraction']:.3f}")
        except Exception as e:  # noqa: BLE001
            import traceback
            traceback.print_exc()
            print(f"[perf] {v} FAILED: {e}")


if __name__ == "__main__":
    main()
