import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST be the first statements of this module —
# before any jax import — since jax locks the device count on first init.
# The module docstring therefore lives in this comment block.
#
# Multi-pod dry-run driver (deliverable e).

# Lowers + compiles every (arch x shape x mesh) cell against
# ShapeDtypeStruct inputs on the production meshes, prints
# memory_analysis()/cost_analysis(), extracts the three roofline terms, and
# caches everything to experiments/dryrun/*.json.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
#       --shape train_4k --mesh pod
#   PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
import argparse
import dataclasses
import json
import time
import traceback

import jax

from ..configs import all_archs, get_config
from ..configs.base import SHAPES, applicable_shapes
from ..models.config import ModelConfig
from ..parallel import sharding as shd
from ..runtime.optimizer import OptConfig
from ..runtime.serve import make_decode_step, make_prefill_step
from ..runtime.train import make_train_step
from . import roofline as rf
from . import specs as SP
from .mesh import make_production_mesh

OUT_DIR = "experiments/dryrun"


def _shardings(tree_specs, mesh):
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(
                            x, jax.sharding.PartitionSpec))


def build_and_lower(arch: str, shape_name: str, mesh, *,
                    fsdp: bool = True, micro_batches: int = 1,
                    grad_compress: bool = False,
                    cfg_override: ModelConfig | None = None,
                    layout: str = "2d", params_bf16: bool = False):
    """Returns (lowered, aux) for one cell.

    layout: "2d" (TP over model + FSDP over data, the baseline) or
    "ddp" (no TP: pure data parallel over ALL axes with ZeRO-3 weight
    sharding — a beyond-paper §Perf layout)."""
    cfg = cfg_override or get_config(arch)
    info = SHAPES[shape_name]
    mode = info["mode"]
    opt_cfg = OptConfig(grad_compress=grad_compress)

    tp = layout != "ddp"
    fsdp_axes = ("data",) if tp else tuple(
        a for a in ("data", "model") if a in mesh.axis_names)
    # pin activation batch sharding to the DP axes (when divisible)
    dp = shd.batch_axes(mesh) if tp else tuple(
        a for a in ("pod", "data", "model") if a in mesh.axis_names)
    dp_size = 1
    for a in dp:
        dp_size *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    eff_batch = info["batch"]
    if mode == "train" and micro_batches > 1:
        eff_batch //= micro_batches
    tp_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get(
        "model", 0) if tp else 0
    if dp and eff_batch % dp_size == 0:
        cfg = dataclasses.replace(cfg, dp_axes=tuple(dp), tp_size=tp_size)
    if mode == "decode":
        # Decode baseline: weights stay 2D-sharded (data x model) and the
        # tiny per-token activations are partial-summed — far cheaper than
        # per-layer weight all-gathers at batch*1 token.
        cfg = dataclasses.replace(cfg, gather_weights=False)

    # serving uses bf16 weights (no optimizer/master copy at serve time)
    import jax.numpy as jnp
    p_sds = SP.param_specs_for(
        cfg, dtype=(jnp.bfloat16 if (params_bf16 or mode != "train")
                    else None))
    p_spec = shd.param_specs(p_sds, mesh, fsdp=fsdp, fsdp_axes=fsdp_axes,
                             tp=tp)
    p_shard = _shardings(p_spec, mesh)

    if mode == "train":
        o_sds = SP.opt_specs_for(cfg, opt_cfg)
        o_spec = shd.param_specs(o_sds, mesh, fsdp=fsdp,
                                 fsdp_axes=fsdp_axes, tp=tp)
        o_shard = _shardings(o_spec, mesh)
        b_sds = SP.batch_specs_for(cfg, shape_name)
        b_spec = shd.batch_specs(b_sds, mesh, axes=dp if not tp else None)
        b_shard = _shardings(b_spec, mesh)
        step = make_train_step(cfg, opt_cfg, micro_batches=micro_batches)
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         donate_argnums=(0, 1))
        with jax.sharding.set_mesh(mesh):
            lowered = jitted.lower(p_sds, o_sds, b_sds)
    elif mode == "prefill":
        b_sds = SP.batch_specs_for(cfg, shape_name)
        b_spec = shd.batch_specs(b_sds, mesh)
        b_shard = _shardings(b_spec, mesh)
        step = make_prefill_step(cfg)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
        with jax.sharding.set_mesh(mesh):
            lowered = jitted.lower(p_sds, b_sds)
    else:  # decode
        c_sds = SP.cache_specs_for(cfg, shape_name)
        c_spec = shd.cache_specs(c_sds, mesh)
        c_shard = _shardings(c_spec, mesh)
        ex = SP.decode_extra_specs(cfg, shape_name)
        t_shard = _shardings(shd.batch_specs(
            {"tokens": ex["tokens"]}, mesh), mesh)["tokens"]
        from jax.sharding import NamedSharding, PartitionSpec as P
        step = make_decode_step(cfg)
        jitted = jax.jit(step,
                         in_shardings=(p_shard, c_shard, t_shard,
                                       NamedSharding(mesh, P())),
                         donate_argnums=(1,))
        with jax.sharding.set_mesh(mesh):
            lowered = jitted.lower(p_sds, c_sds, ex["tokens"], ex["pos"])
    return lowered, dict(cfg=cfg, info=info, mode=mode)


def probe_cfgs(cfg: ModelConfig):
    """(1-unit cfg, 2-unit cfg, n_units) for exact per-layer cost probes.

    XLA's cost_analysis counts a lax.scan (while-loop) body ONCE, so the
    scanned full model under-reports FLOPs/bytes/collectives by ~n_layers x.
    We compile UNROLLED 1-unit and 2-unit variants and extrapolate
    linearly: total = p1 + (n_units - 1) * (p2 - p1)."""
    r = dataclasses.replace
    if cfg.kind == "hybrid":
        e = cfg.hybrid_attn_every
        return (r(cfg, n_layers=e, scan_layers=False),
                r(cfg, n_layers=2 * e, scan_layers=False),
                cfg.n_layers // e)
    if cfg.kind == "encdec":
        return (r(cfg, n_layers=1, n_enc_layers=1, scan_layers=False),
                r(cfg, n_layers=2, n_enc_layers=2, scan_layers=False),
                cfg.n_layers)
    return (r(cfg, n_layers=1, scan_layers=False),
            r(cfg, n_layers=2, scan_layers=False),
            cfg.n_layers)


def _probe_cost(arch, shape_name, mesh, n_chips, cfg, **kw):
    lowered, _ = build_and_lower(arch, shape_name, mesh, cfg_override=cfg,
                                 **kw)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = rf.parse_collective_bytes(compiled.as_text(), n_chips)
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def extrapolated_cost(arch, shape_name, mesh, n_chips, cfg, **kw):
    c1_cfg, c2_cfg, n_units = probe_cfgs(cfg)
    p1 = _probe_cost(arch, shape_name, mesh, n_chips, c1_cfg, **kw)
    p2 = _probe_cost(arch, shape_name, mesh, n_chips, c2_cfg, **kw)
    k = n_units - 1

    def lin(a, b):
        return a + k * (b - a)
    coll = {}
    for key in rf.COLLECTIVES + ("total",):
        coll[key] = lin(p1["coll"][key], p2["coll"][key])
    coll["counts"] = {key: lin(p1["coll"]["counts"][key],
                               p2["coll"]["counts"][key])
                      for key in rf.COLLECTIVES}
    return {"flops": lin(p1["flops"], p2["flops"]),
            "bytes accessed": lin(p1["bytes"], p2["bytes"])}, coll


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             fsdp: bool = True, micro_batches: int = 1,
             grad_compress: bool = False, save: bool = True,
             tag: str = "", cfg_override=None, verbose: bool = True,
             probes: bool = True, layout: str = "2d",
             params_bf16: bool = False):
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    n_chips = mesh.devices.size
    t0 = time.time()
    lowered, aux = build_and_lower(
        arch, shape_name, mesh, fsdp=fsdp, micro_batches=micro_batches,
        grad_compress=grad_compress, cfg_override=cfg_override,
        layout=layout, params_bf16=params_bf16)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    if probes:
        # exact per-layer costs from unrolled 1/2-unit probe compiles.
        # micro_batches is forced to 1: the grad-accum scan is also a
        # while loop (counted once), and per-step totals are identical.
        cost, coll = extrapolated_cost(
            arch, shape_name, mesh, n_chips, aux["cfg"], fsdp=fsdp,
            micro_batches=1, grad_compress=grad_compress, layout=layout,
            params_bf16=params_bf16)
    else:
        cost = compiled.cost_analysis()
        coll = rf.parse_collective_bytes(compiled.as_text(), n_chips)
    mflops = rf.model_flops_for(aux["cfg"], aux["info"])
    terms = rf.roofline(cost, coll, n_chips, mflops, aux["mode"])

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "tag": tag, "fsdp": fsdp, "micro_batches": micro_batches,
        "grad_compress": grad_compress,
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            "per_device_total": (mem.argument_size_in_bytes
                                 + mem.output_size_in_bytes
                                 - mem.alias_size_in_bytes
                                 + mem.temp_size_in_bytes),
        },
        "roofline": terms,
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}{tag} "
              f"chips={n_chips} lower={t_lower:.0f}s compile={t_compile:.0f}s")
        print(f"  memory/device: args={mem.argument_size_in_bytes/1e9:.2f}GB "
              f"temp={mem.temp_size_in_bytes/1e9:.2f}GB")
        print(f"  terms: compute={terms['compute_s']*1e3:.2f}ms "
              f"memory={terms['memory_s']*1e3:.2f}ms "
              f"collective={terms['collective_s']*1e3:.2f}ms "
              f"-> {terms['dominant']} bound; "
              f"useful-flops ratio={terms['model_flops_ratio']:.2f}")
    if save:
        os.makedirs(OUT_DIR, exist_ok=True)
        fn = f"{OUT_DIR}/{arch}__{shape_name}__{mesh_name}{tag}.json"
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    args = ap.parse_args()

    archs = all_archs() if (args.all or not args.arch) else [args.arch]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    ok, fail = 0, []
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([args.shape] if args.shape
                  else applicable_shapes(cfg))
        for shape in shapes:
            for mesh_name in meshes:
                fn = f"{OUT_DIR}/{arch}__{shape}__{mesh_name}{args.tag}.json"
                if args.skip_existing and os.path.exists(fn):
                    print(f"[skip] {fn}")
                    ok += 1
                    continue
                try:
                    # roofline probes are single-pod only (DESIGN §5); the
                    # multipod pass proves the "pod" axis shards/compiles.
                    run_cell(arch, shape, mesh_name,
                             fsdp=not args.no_fsdp,
                             micro_batches=args.micro_batches,
                             grad_compress=args.grad_compress,
                             tag=args.tag,
                             probes=(mesh_name == "pod"
                                     and not args.no_probes))
                    ok += 1
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    fail.append((arch, shape, mesh_name, repr(e)[:200]))
    print(f"\n[dryrun] {ok} cells OK, {len(fail)} failed")
    for f in fail:
        print("  FAIL:", f)
    raise SystemExit(1 if fail else 0)


if __name__ == "__main__":
    main()
