"""Production meshes (DESIGN.md §5).

A function, not a module constant, so importing never touches jax device
state.  Single pod: 16x16 = 256 chips ("data", "model").  Multi-pod:
2x16x16 = 512 chips ("pod", "data", "model") — the "pod" axis carries the
inter-pod (Ethernet/DCN) data parallelism that STrack accelerates.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (1,1) smoke meshes)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
