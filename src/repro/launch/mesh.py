"""Production meshes (DESIGN.md §5).

A function, not a module constant, so importing never touches jax device
state.  Single pod: 16x16 = 256 chips ("data", "model").  Multi-pod:
2x16x16 = 512 chips ("pod", "data", "model") — the "pod" axis carries the
inter-pod (Ethernet/DCN) data parallelism that STrack accelerates.

Mesh construction goes through ``repro.compat`` so the same call works on
JAX versions with and without ``axis_types`` / ``AxisType``.
"""
from __future__ import annotations

from ..compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (1,1) smoke meshes)."""
    return _compat_make_mesh(shape, axes)
