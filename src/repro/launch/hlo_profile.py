"""HLO collective profiler — the dry-run's 'profile view' for §Perf.

Lists the largest collectives (bytes, op, source op_name metadata) from a
compiled module so hillclimbing can target the dominant resharding /
gradient traffic."""
from __future__ import annotations

import re

from .roofline import COLLECTIVES, _result_bytes, _group_size

_META_RE = re.compile(r'op_name="([^"]+)"')


def top_collectives(hlo_text: str, n_devices: int, top: int = 25):
    rows = []
    for line in hlo_text.splitlines():
        for op in COLLECTIVES:
            key, key_s = f" {op}(", f" {op}-start("
            if key in line or key_s in line:
                opk = op + ("-start" if key_s in line else "")
                rb = _result_bytes(line, opk)
                g = _group_size(line, n_devices)
                if op == "all-reduce":
                    moved = 2.0 * rb * (g - 1) / max(g, 1)
                elif op == "reduce-scatter":
                    moved = rb * (g - 1)
                elif op == "collective-permute":
                    moved = rb
                else:
                    moved = rb * (g - 1) / max(g, 1)
                m = _META_RE.search(line)
                rows.append((moved, op, g, m.group(1) if m else "?"))
                break
    rows.sort(reverse=True)
    return rows[:top]


def summarize(hlo_text: str, n_devices: int, top: int = 25) -> str:
    rows = top_collectives(hlo_text, n_devices, top)
    out = [f"{'bytes/dev':>12}  {'op':<18} {'grp':>4}  source"]
    for moved, op, g, src in rows:
        out.append(f"{moved/1e6:>10.1f}MB  {op:<18} {g:>4}  {src[:90]}")
    return "\n".join(out)
