"""Sharding rules: param/batch/cache pytrees -> PartitionSpec pytrees.

Logical layout (production mesh, DESIGN.md §5):
  * batch                 -> ("pod", "data")   [DP across pods + within pod]
  * TP (d_ff, heads, vocab) -> "model"
  * FSDP (params + optimizer state)  -> "data" on the non-TP weight dim
  * KV-cache sequence      -> "model" (sequence-sharded serving)

Every rule degrades to replication when the dim is not divisible by the
axis size — so batch=1 long-context decode, 8-expert MoE on a 16-way axis,
etc. all lower cleanly on the fixed production mesh.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

DP_AXES = ("pod", "data")   # batch axes (pod may be absent on 1-pod meshes)


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= _axis_size(mesh, a)
        return n
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _maybe(mesh: Mesh, axis, dim: int) -> Optional[str]:
    """axis if it exists and divides dim, else None (replicate)."""
    if isinstance(axis, tuple):
        axis = tuple(a for a in axis if _axis_size(mesh, a) > 1)
        if not axis:
            return None
        if len(axis) == 1:
            axis = axis[0]
    size = _axis_size(mesh, axis)
    if size > 1 and dim % size == 0:
        return axis
    return None


def batch_axes(mesh: Mesh):
    return tuple(a for a in DP_AXES if a in mesh.axis_names)


def _rule_for_param(mesh: Mesh, path: str, shape, fsdp: bool,
                    fsdp_axes=("data",), tp: bool = True) -> P:
    """One leaf -> PartitionSpec. `path` is a '/'-joined key string."""
    name = path.split("/")[-1]
    nd = len(shape)
    if nd == 0:
        return P()
    M = "model" if tp else None
    D = (tuple(fsdp_axes) if len(fsdp_axes) > 1 else fsdp_axes[0]) \
        if fsdp else None

    def spec(*axes):
        # pad leading None for stacked-layer (or expert) leading dims,
        # then validate divisibility per dim (replicate when it fails)
        full = (None,) * (nd - len(axes)) + tuple(axes)
        out = [None if ax is None else _maybe(mesh, ax, shape[i])
               for i, ax in enumerate(full)]
        return P(*out)

    if name in ("embed",):
        return spec(M, D)
    if name in ("lm_head",):
        return spec(D, M)
    # attention / mlp projections (2 trailing dims)
    if name in ("wq", "wk", "wv"):
        return spec(D, M)
    if name == "wo":
        return spec(M, D)
    if name in ("wg", "wu"):            # mlp (…,d,ff) OR moe (…,E,d,ff)
        return spec(D, M)
    if name == "wd":                    # mlp (…,ff,d) OR moe (…,E,ff,d)
        return spec(M, D)
    if name == "router":
        return spec(D, None)
    # ssm
    if name in ("w_z", "w_x"):
        return spec(D, M)
    if name in ("w_B", "w_C"):
        return spec(D, None)
    if name == "w_dt":
        return spec(D, M)
    if name == "w_out":
        return spec(M, D)
    if name in ("conv_x",):
        return spec(None, M)
    if name in ("norm_w", "conv_bx"):
        return spec(M)
    if name in ("A_log", "D", "dt_bias"):
        return spec(M)
    # everything else (norms, small biases): replicated
    return P(*([None] * nd))


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def param_specs(param_shapes, mesh: Mesh, fsdp: bool = True,
                fsdp_axes=("data",), tp: bool = True):
    """param_shapes: pytree of ShapeDtypeStruct/arrays -> pytree of P."""
    paths, leaves, treedef = _tree_paths(param_shapes)
    specs = [_rule_for_param(mesh, p, l.shape, fsdp, fsdp_axes, tp)
             for p, l in zip(paths, leaves)]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(batch_shapes, mesh: Mesh, axes=None):
    dp = tuple(axes) if axes else batch_axes(mesh)

    def rule(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        b = _maybe(mesh, dp, leaf.shape[0])
        return P(b, *([None] * (nd - 1)))
    paths, leaves, treedef = _tree_paths(batch_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [rule(p, l) for p, l in zip(paths, leaves)])


def cache_specs(cache_shapes, mesh: Mesh):
    """KV/SSM cache: batch -> DP axes, sequence/heads -> model."""
    dp = batch_axes(mesh)

    def rule(path, leaf):
        name = path.split("/")[-1]
        shape = leaf.shape
        nd = len(shape)
        if name in ("k", "v"):
            # (L, B, S, K, hd): batch->dp, seq->model
            b = _maybe(mesh, dp, shape[1])
            s = _maybe(mesh, "model", shape[2])
            return P(None, b, s, None, None)
        if name == "state":
            # (L, B, H, N, P): batch->dp, heads->model
            b = _maybe(mesh, dp, shape[1])
            h = _maybe(mesh, "model", shape[2])
            return P(None, b, h, None, None)
        if name.startswith("conv"):
            b = _maybe(mesh, dp, shape[1])
            c = _maybe(mesh, "model", shape[-1])
            return P(None, b, None, c)
        if name == "enc_out":
            b = _maybe(mesh, dp, shape[0])
            return P(b, None, None)
        return P(*([None] * nd))
    paths, leaves, treedef = _tree_paths(cache_shapes)
    return jax.tree_util.tree_unflatten(
        treedef, [rule(p, l) for p, l in zip(paths, leaves)])


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
