"""The composed STrack flow engine — one NamedTuple per flow, pure-JAX.

``FlowState`` bundles CC (Algo 3/4), spray (Algo 2) and reliability (S3.3)
state; ``flow_on_sack`` / ``flow_next_packet`` / ``flow_on_timer`` are the
three entry points of Algorithm 1.  Everything is fixed-shape, so
``jax.vmap`` turns this into N parallel NIC connection engines, and
``sim/fabric.py`` (multi-queue fat-tree; ``sim/jaxsim.py`` is its 1-queue
incast special case) scans them through time inside a single XLA program —
each engine seeing genuinely divergent per-path ECN/RTT signals.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import cc as cc_mod
from . import lb as lb_mod
from . import reliability as rel_mod
from .params import STrackParams
from .reliability import RelState, SackMsg
from .cc import CCState
from .lb import SprayState


class FlowState(NamedTuple):
    cc: CCState
    spray: SprayState
    rel: RelState


def init_flow(p: STrackParams, total_pkts, now: float = 0.0,
              tail_bytes=None) -> FlowState:
    """``tail_bytes`` is the wire size of the final PSN (the message's odd
    tail); None means a full MTU (uniform-size messages)."""
    return FlowState(
        cc=cc_mod.init_cc(p, now),
        spray=lb_mod.init_spray(p, now),
        rel=rel_mod.init_rel(p, total_pkts, now, tail_bytes),
    )


def flow_on_sack(fs: FlowState, p: STrackParams, sack: SackMsg,
                 now: jax.Array) -> FlowState:
    """Algorithm 1, on_receiving_ack — guarded by ``sack.valid``."""
    now = jnp.asarray(now, jnp.float32)
    measured_rtt = now - sack.ts
    base_rtt = jnp.minimum(fs.cc.base_rtt, measured_rtt)
    qdelay = measured_rtt - base_rtt

    spray = lb_mod.update_ecn_bitmap(fs.spray, sack.ecn, sack.entropy)
    spray = jax.tree.map(
        lambda new, old: jnp.where(sack.probe_reply, old, new),
        spray, fs.spray)

    rel, acked_bytes = rel_mod.rel_on_sack(
        fs.rel, p, sack, fs.cc.cwnd, fs.cc.achieved_bdp_pkts, qdelay, now)

    cc = fs.cc._replace(base_rtt=base_rtt)
    cc = cc_mod.update_achieved_bdp(cc, p, acked_bytes, sack.probe_reply, now)
    cc = cc_mod.adjust_cwnd(cc, p, sack.ecn, qdelay, now)

    new = FlowState(cc=cc, spray=spray, rel=rel)
    # No-op when the SACK slot is empty (vectorised simulators pass bubbles).
    return jax.tree.map(
        lambda n, o: jnp.where(sack.valid, n, o), new, fs)


class TxPacket(NamedTuple):
    valid: jax.Array    # bool
    psn: jax.Array      # i32
    entropy: jax.Array  # i32
    is_rtx: jax.Array   # bool
    is_probe: jax.Array  # bool


def flow_next_packet(fs: FlowState, p: STrackParams, now: jax.Array,
                     ) -> tuple[FlowState, TxPacket]:
    """on_sending_packet: window check + PSN pick + Algo 2 path choice."""
    rel, psn, is_rtx, valid = rel_mod.rel_next_psn(fs.rel, p, fs.cc.cwnd)
    entropy, spray = lb_mod.choose_path(fs.spray, p, fs.cc.cwnd, now)
    spray = jax.tree.map(
        lambda n, o: jnp.where(valid, n, o), spray, fs.spray)
    rel = jax.tree.map(lambda n, o: jnp.where(valid, n, o), rel, fs.rel)
    return (FlowState(cc=fs.cc, spray=spray, rel=rel),
            TxPacket(valid=valid, psn=psn, entropy=entropy, is_rtx=is_rtx,
                     is_probe=jnp.zeros((), bool)))


def flow_on_timer(fs: FlowState, p: STrackParams, now: jax.Array,
                  ) -> tuple[FlowState, TxPacket]:
    """RTO / probe timers; may emit a probe packet."""
    rel, probe = rel_mod.rel_on_timer(fs.rel, p, now)
    entropy, spray = lb_mod.choose_path(fs.spray, p, fs.cc.cwnd, now)
    spray = jax.tree.map(lambda n, o: jnp.where(probe, n, o), spray, fs.spray)
    return (FlowState(cc=fs.cc, spray=spray, rel=rel),
            TxPacket(valid=probe, psn=rel.epsn, entropy=entropy,
                     is_rtx=jnp.zeros((), bool), is_probe=probe))


def flow_done(fs: FlowState) -> jax.Array:
    return rel_mod.rel_done(fs.rel)


def flow_next_event(fs: FlowState, p: STrackParams,
                    ) -> tuple[jax.Array, jax.Array]:
    """(next timer event time, next pacing release time) for the
    event-horizon scan in ``sim/fabric.py``.

    Before the earlier of the probe and RTO deadlines, ``flow_on_timer``
    is provably a no-op, and STrack's window CC has no pacing gate —
    ``flow_next_packet`` validity is time-independent — so the send slot
    never wakes the fabric on its own (+inf).
    """
    del p
    active = ~rel_mod.rel_done(fs.rel)
    timer_ev = jnp.where(
        active, jnp.minimum(fs.rel.probe_deadline, fs.rel.rto_deadline),
        jnp.inf)
    return timer_ev, jnp.full_like(timer_ev, jnp.inf)
