"""Section 3.3 — STrack reliability in fixed-shape JAX form.

A real NIC ASIC tracks reordering with *fixed-size* bitmaps; this module is
the JAX mirror of that hardware: the receiver keeps a ``W``-bit arrival
bitmap anchored at EPSN, the sender keeps ``W``-bit sacked/claimed bitmaps.
All control flow is jnp.where / fixed-length vector ops so the whole thing
vmaps across flows.

Packet sizes: every PSN is a full MTU except the message's final PSN,
whose wire size is the message's odd tail (``RelState.tail_bytes``,
mirroring ``ref.STrackSender.pkt_size``).  The sent/claimed byte ledgers
account that tail exactly, so sub-MTU messages and odd tails keep
``inflight_bytes`` consistent with the receiver's ``bytes_recvd``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .params import STrackParams

REORDER_WINDOW = 512  # W: receiver/sender reorder window, packets


class SackMsg(NamedTuple):
    """The SACK wire format of Fig. 7 (plus echoed path/ts/ecn)."""

    valid: jax.Array        # bool: was a SACK emitted
    epsn: jax.Array         # i32
    sack_base: jax.Array    # i32
    sack_bits: jax.Array    # bool[sack_bitmap_bits]
    bytes_recvd: jax.Array  # f32
    ooo_cnt: jax.Array      # i32
    ecn: jax.Array          # bool (echoed)
    entropy: jax.Array      # i32 (echoed)
    ts: jax.Array           # f32 (echoed send timestamp)
    probe_reply: jax.Array  # bool


class ReceiverState(NamedTuple):
    epsn: jax.Array             # i32
    bitmap: jax.Array           # bool[W] relative to epsn (bit 0 == epsn)
    bytes_recvd: jax.Array      # f32, deduplicated
    bytes_since_sack: jax.Array  # f32
    lpsn: jax.Array             # i32, -1 = invalid
    total_pkts: jax.Array       # i32


def init_receiver(total_pkts) -> ReceiverState:
    return ReceiverState(
        epsn=jnp.zeros((), jnp.int32),
        bitmap=jnp.zeros((REORDER_WINDOW,), bool),
        bytes_recvd=jnp.zeros((), jnp.float32),
        bytes_since_sack=jnp.zeros((), jnp.float32),
        lpsn=jnp.full((), -1, jnp.int32),
        total_pkts=jnp.asarray(total_pkts, jnp.int32),
    )


def _shift_left(bitmap: jax.Array, shift: jax.Array) -> jax.Array:
    """bitmap <<= shift, zero-filled (shift is traced)."""
    n = bitmap.shape[0]
    rolled = jnp.roll(bitmap, -shift)
    keep = jnp.arange(n) < (n - shift)
    return rolled & keep


def receiver_on_data(rs: ReceiverState, p: STrackParams, psn: jax.Array,
                     size: jax.Array, ecn: jax.Array, entropy: jax.Array,
                     ts: jax.Array, is_probe: jax.Array,
                     ) -> tuple[ReceiverState, SackMsg]:
    """Process one data/probe packet; maybe emit a SACK (Section 3.3.1)."""
    W = REORDER_WINDOW
    psn = jnp.asarray(psn, jnp.int32)
    rel = psn - rs.epsn
    relc = jnp.clip(rel, 0, W - 1)
    inwin = (rel >= 0) & (rel < W)
    already = jnp.where(rel < 0, True, rs.bitmap[relc] & inwin)
    new = (~already) & inwin & (~is_probe)

    bitmap = jnp.where(new, rs.bitmap.at[relc].set(True), rs.bitmap)
    got = jnp.where(new, jnp.asarray(size, jnp.float32), 0.0)
    bytes_recvd = rs.bytes_recvd + got
    bytes_since_sack = rs.bytes_since_sack + got

    # Advance EPSN past the contiguous prefix of arrivals.
    all_set = jnp.all(bitmap)
    shift = jnp.where(bitmap[0],
                      jnp.where(all_set, W, jnp.argmax(~bitmap)), 0
                      ).astype(jnp.int32)
    epsn = rs.epsn + shift
    bitmap = _shift_left(bitmap, shift)

    lpsn = jnp.where(new & ((rs.lpsn < 0) | (psn < rs.lpsn)), psn, rs.lpsn)

    trigger = (bytes_since_sack >= p.ack_coalesce_bytes) \
        | (new & (rel == 0)) | is_probe | (epsn >= rs.total_pkts)

    # SACK segment containing the lowest PSN since the last SACK.
    lpsn_eff = jnp.maximum(jnp.where(lpsn < 0, epsn, lpsn), epsn)
    seg = (lpsn_eff - epsn) // p.sack_bitmap_bits
    base = epsn + seg * p.sack_bitmap_bits
    off = base - epsn
    padded = jnp.concatenate([bitmap, jnp.zeros((p.sack_bitmap_bits,), bool)])
    sack_bits = jax.lax.dynamic_slice(padded, (off,), (p.sack_bitmap_bits,))

    sack = SackMsg(
        valid=trigger,
        epsn=epsn,
        sack_base=base,
        sack_bits=sack_bits,
        bytes_recvd=bytes_recvd,
        ooo_cnt=jnp.sum(bitmap).astype(jnp.int32),
        ecn=jnp.asarray(ecn, bool),
        entropy=jnp.asarray(entropy, jnp.int32),
        ts=jnp.asarray(ts, jnp.float32),
        probe_reply=jnp.asarray(is_probe, bool),
    )
    new_rs = ReceiverState(
        epsn=epsn,
        bitmap=bitmap,
        bytes_recvd=bytes_recvd,
        bytes_since_sack=jnp.where(trigger, 0.0, bytes_since_sack),
        lpsn=jnp.where(trigger, jnp.int32(-1), lpsn),
        total_pkts=rs.total_pkts,
    )
    return new_rs, sack


class RelState(NamedTuple):
    """Sender-side reliability ledger (Section 3.3.2)."""

    epsn: jax.Array          # i32: receiver's cumulative ack point
    sacked: jax.Array        # bool[W] rel. to epsn
    claimed: jax.Array       # bool[W]: declared lost, not yet re-sent
    psn_next: jax.Array      # i32
    total_pkts: jax.Array    # i32
    tail_bytes: jax.Array    # f32: wire size of the final PSN (odd tail)
    bytes_sent: jax.Array    # f32
    bytes_recvd_seen: jax.Array  # f32
    bytes_claimed: jax.Array     # f32
    in_recovery: jax.Array   # bool
    recover_high: jax.Array  # i32
    probe_deadline: jax.Array  # f32
    rto_deadline: jax.Array    # f32
    done_ts: jax.Array         # f32, -1 until done
    rto_fires: jax.Array       # i32: RTO expirations (recovery observability)
    recoveries: jax.Array      # i32: SACK-triggered recovery entries


def init_rel(p: STrackParams, total_pkts, now: float = 0.0,
             tail_bytes=None) -> RelState:
    W = REORDER_WINDOW
    if tail_bytes is None:
        tail_bytes = float(p.mtu_bytes)
    return RelState(
        epsn=jnp.zeros((), jnp.int32),
        sacked=jnp.zeros((W,), bool),
        claimed=jnp.zeros((W,), bool),
        psn_next=jnp.zeros((), jnp.int32),
        total_pkts=jnp.asarray(total_pkts, jnp.int32),
        tail_bytes=jnp.asarray(tail_bytes, jnp.float32),
        bytes_sent=jnp.zeros((), jnp.float32),
        bytes_recvd_seen=jnp.zeros((), jnp.float32),
        bytes_claimed=jnp.zeros((), jnp.float32),
        in_recovery=jnp.zeros((), bool),
        recover_high=jnp.full((), -1, jnp.int32),
        probe_deadline=jnp.full((), now + p.probe_rtts * p.base_rtt_us,
                                jnp.float32),
        rto_deadline=jnp.full((), now + p.rto_us, jnp.float32),
        done_ts=jnp.full((), -1.0, jnp.float32),
        rto_fires=jnp.zeros((), jnp.int32),
        recoveries=jnp.zeros((), jnp.int32),
    )


def inflight_bytes(rel: RelState) -> jax.Array:
    return rel.bytes_sent - rel.bytes_recvd_seen - rel.bytes_claimed


def rel_done(rel: RelState) -> jax.Array:
    return rel.epsn >= rel.total_pkts


def pkt_wire_bytes(rel: RelState, p: STrackParams,
                   psn: jax.Array) -> jax.Array:
    """Wire size of one data PSN: full MTU, except the odd tail packet."""
    return jnp.where(psn >= rel.total_pkts - 1, rel.tail_bytes,
                     jnp.float32(p.mtu_bytes))


def _mask_wire_bytes(mask: jax.Array, epsn: jax.Array, rel: RelState,
                     p: STrackParams) -> jax.Array:
    """Total wire bytes of the PSNs flagged in ``mask`` (a W-bitmap
    anchored at ``epsn``): full MTUs except the message's final PSN."""
    W = mask.shape[0]
    n = jnp.sum(mask).astype(jnp.float32)
    tail_rel = rel.total_pkts - 1 - epsn
    tail_in = (tail_rel >= 0) & (tail_rel < W)
    tail_flag = mask[jnp.clip(tail_rel, 0, W - 1)] & tail_in
    return n * p.mtu_bytes - jnp.where(
        tail_flag, p.mtu_bytes - rel.tail_bytes, 0.0)


def _enter_recovery(rel: RelState, p: STrackParams, high: jax.Array,
                    enter: jax.Array) -> RelState:
    """Declare unsacked/unclaimed packets in [epsn, high) lost."""
    W = REORDER_WINDOW
    high = jnp.maximum(rel.recover_high, high)
    span = jnp.arange(W) < jnp.clip(high - rel.epsn, 0, W)
    lost = span & (~rel.sacked) & (~rel.claimed) \
        & (jnp.arange(W) + rel.epsn < rel.psn_next)
    lost = lost & enter
    return rel._replace(
        claimed=rel.claimed | lost,
        bytes_claimed=rel.bytes_claimed + _mask_wire_bytes(lost, rel.epsn,
                                                           rel, p),
        in_recovery=rel.in_recovery | enter,
        recover_high=jnp.where(enter, high, rel.recover_high),
    )


def rel_on_sack(rel: RelState, p: STrackParams, sack: SackMsg,
                cwnd_pkts: jax.Array, achieved_bdp_pkts: jax.Array,
                qdelay: jax.Array, now: jax.Array,
                ) -> tuple[RelState, jax.Array]:
    """Apply one SACK. Returns (new_state, newly_acked_bytes)."""
    W = REORDER_WINDOW
    now = jnp.asarray(now, jnp.float32)

    # --- probe-based loss detection (Algo 1 line 13) ---
    probe_loss = sack.probe_reply & (qdelay < 2 * p.base_rtt_us) \
        & (achieved_bdp_pkts == 0.0) & (~rel_done(rel))

    # --- cumulative advance ---
    shift = jnp.clip(sack.epsn - rel.epsn, 0, W).astype(jnp.int32)
    advanced = shift > 0
    idx = jnp.arange(W)
    # claimed-but-now-acked packets shifting out: un-claim their bytes
    unclaim_out = rel.claimed & (idx < shift)
    sacked = _shift_left(rel.sacked, shift)
    claimed = _shift_left(rel.claimed, shift)
    epsn = rel.epsn + shift
    bytes_claimed = rel.bytes_claimed - _mask_wire_bytes(unclaim_out,
                                                         rel.epsn, rel, p)

    # --- selective bits ---
    off = sack.sack_base - epsn  # may be negative (stale segment)
    bits = sack.sack_bits
    nbits = bits.shape[0]
    placed = jnp.zeros((W + nbits,), bool)
    placed = jax.lax.dynamic_update_slice(
        placed, bits, (jnp.clip(off, 0, W),))[:W]
    placed = placed & (off >= 0)  # drop stale segments entirely for safety
    newly = placed & (~sacked)
    unclaim_sel = newly & claimed
    bytes_claimed = bytes_claimed - _mask_wire_bytes(unclaim_sel, epsn,
                                                     rel, p)
    sacked = sacked | placed
    claimed = claimed & (~unclaim_sel)

    acked_bytes = jnp.maximum(0.0, sack.bytes_recvd - rel.bytes_recvd_seen)
    bytes_recvd_seen = jnp.maximum(rel.bytes_recvd_seen, sack.bytes_recvd)

    rel = rel._replace(
        epsn=epsn, sacked=sacked, claimed=claimed,
        bytes_claimed=bytes_claimed, bytes_recvd_seen=bytes_recvd_seen,
        probe_deadline=now + p.probe_rtts * p.base_rtt_us,
        rto_deadline=jnp.where(advanced, now + p.rto_us, rel.rto_deadline),
    )

    # --- OOO-based loss detection ---
    thresh = jnp.maximum(cwnd_pkts, float(p.min_ooo_threshold))
    any_sacked = jnp.any(sacked)
    high_sacked = epsn + jnp.where(
        any_sacked, W - jnp.argmax(sacked[::-1]), 0).astype(jnp.int32)
    ooo_loss = (sack.ooo_cnt.astype(jnp.float32) > thresh) & sack.valid
    enter = ooo_loss | probe_loss
    high = jnp.where(probe_loss, rel.psn_next,
                     jnp.where(any_sacked, high_sacked, epsn))
    fresh_entry = enter & (~rel.in_recovery)
    rel = _enter_recovery(rel, p, high, enter)
    rel = rel._replace(
        recoveries=rel.recoveries + fresh_entry.astype(jnp.int32))

    # --- recovery exit ---
    exit_rec = rel.in_recovery & (rel.epsn >= rel.recover_high)
    rel = rel._replace(
        in_recovery=rel.in_recovery & (~exit_rec),
        recover_high=jnp.where(exit_rec, jnp.int32(-1), rel.recover_high),
        done_ts=jnp.where(rel_done(rel) & (rel.done_ts < 0), now,
                          rel.done_ts),
    )
    return rel, acked_bytes


def rel_next_psn(rel: RelState, p: STrackParams, cwnd_pkts: jax.Array,
                 ) -> tuple[RelState, jax.Array, jax.Array, jax.Array]:
    """Pick the next PSN to transmit. Returns (state, psn, is_rtx, valid)."""
    W = REORDER_WINDOW
    has_rtx = jnp.any(rel.claimed)
    window_ok = inflight_bytes(rel) < cwnd_pkts * p.mtu_bytes
    seq_ok = rel.psn_next - rel.epsn < W  # keep ledger in-window
    has_new = (rel.psn_next < rel.total_pkts) & seq_ok
    valid = (~rel_done(rel)) & window_ok & (has_rtx | has_new)

    rtx_rel = jnp.argmax(rel.claimed).astype(jnp.int32)
    use_rtx = valid & has_rtx
    psn = jnp.where(use_rtx, rel.epsn + rtx_rel, rel.psn_next)
    claimed = jnp.where(use_rtx, rel.claimed.at[rtx_rel].set(False),
                        rel.claimed)
    psn_next = jnp.where(valid & (~has_rtx), rel.psn_next + 1, rel.psn_next)
    bytes_sent = rel.bytes_sent + jnp.where(
        valid, pkt_wire_bytes(rel, p, psn), 0.0)
    return (rel._replace(claimed=claimed, psn_next=psn_next,
                         bytes_sent=bytes_sent),
            psn, use_rtx, valid)


def rel_on_timer(rel: RelState, p: STrackParams, now: jax.Array,
                 ) -> tuple[RelState, jax.Array]:
    """RTO + probe timers. Returns (state, send_probe)."""
    now = jnp.asarray(now, jnp.float32)
    active = ~rel_done(rel)
    rto = active & (now >= rel.rto_deadline)
    rel = _enter_recovery(rel, p, rel.psn_next, rto)
    rel = rel._replace(
        rto_deadline=jnp.where(rto, now + p.rto_us, rel.rto_deadline),
        rto_fires=rel.rto_fires + rto.astype(jnp.int32))
    probe = active & (~rto) & (now >= rel.probe_deadline)
    rel = rel._replace(
        probe_deadline=jnp.where(
            probe, now + p.probe_rtts * p.base_rtt_us, rel.probe_deadline))
    return rel, probe
