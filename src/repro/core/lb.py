"""Algorithm 2 — STrack adaptive load balancing, as pure JAX functions.

State is a fixed-shape NamedTuple so thousands of flows vmap into one XLA
program (the "parallel connection engines" of the NIC ASIC). Semantics match
``core/ref.py`` (the prose-reconciled Algorithm 2): ``bitmap[p] == 1`` means
entropy ``p`` returned an ECN-marked ACK; CHOOSE_PATH round-robins across the
first ``min(max_paths, max(8, 2*cwnd))`` entropies skipping marked ones and
clears the first skipped mark ("one packet only clears one bit").
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .params import STrackParams


class SprayState(NamedTuple):
    bitmap: jax.Array        # int8[max_paths], 1 = ECN-marked (bad)
    rr: jax.Array            # int32 scalar, round-robin pointer
    next_path_id: jax.Array  # int32 scalar, -1 = invalid
    last_reset_ts: jax.Array  # float32 scalar


def init_spray(p: STrackParams, now: float = 0.0) -> SprayState:
    return SprayState(
        bitmap=jnp.zeros((p.max_paths,), jnp.int8),
        rr=jnp.zeros((), jnp.int32),
        next_path_id=jnp.full((), -1, jnp.int32),
        last_reset_ts=jnp.full((), now, jnp.float32),
    )


def update_ecn_bitmap(s: SprayState, ecn: jax.Array,
                      path_id: jax.Array) -> SprayState:
    """UPDATE_ECN_BITMAP(ecn, path_id)."""
    ecn = jnp.asarray(ecn, bool)
    path_id = jnp.asarray(path_id, jnp.int32)
    bitmap = s.bitmap.at[path_id].set(jnp.where(ecn, 1, 0).astype(jnp.int8))
    next_path_id = jnp.where(ecn, jnp.int32(-1), path_id)
    return s._replace(bitmap=bitmap, next_path_id=next_path_id)


def choose_path(s: SprayState, p: STrackParams, cwnd_pkts: jax.Array,
                now: jax.Array) -> tuple[jax.Array, SprayState]:
    """CHOOSE_PATH() -> (entropy, new_state)."""
    now = jnp.asarray(now, jnp.float32)
    # Staleness reset (1-2 RTTs, Section 1 / ref.py).
    do_reset = (now - s.last_reset_ts) > (p.bitmap_reset_rtts * p.base_rtt_us)
    bitmap = jnp.where(do_reset, jnp.zeros_like(s.bitmap), s.bitmap)
    last_reset_ts = jnp.where(do_reset, now, s.last_reset_ts)

    paths = jnp.clip(
        (2.0 * cwnd_pkts).astype(jnp.int32), 8, p.max_paths)

    # Round-robin scan c_0, c_1, ... (c_i = (rr+1+i) mod paths).
    idx = (s.rr + 1 + jnp.arange(p.max_paths, dtype=jnp.int32)) % paths
    c0 = idx[0]
    c0_marked = bitmap[c0] != 0
    # "one packet only clears one bit": the first visited-and-skipped path.
    bitmap_cleared = bitmap.at[c0].set(0)  # no-op when c0 already unmarked
    # First i >= 1 whose (post-clear) bitmap entry is unmarked; all-marked
    # wraps back to the freshly cleared c0 (argmax of all-False -> 0 -> idx[0]).
    unmarked = bitmap_cleared[idx] == 0
    unmarked = unmarked.at[0].set(False)
    k = jnp.argmax(unmarked)
    scanned = jnp.where(c0_marked, idx[k], c0)

    rr_new = jnp.where(s.next_path_id >= 0, s.next_path_id, scanned)
    new_bitmap = jnp.where(s.next_path_id >= 0, bitmap, bitmap_cleared)
    return rr_new, SprayState(
        bitmap=new_bitmap,
        rr=rr_new,
        next_path_id=jnp.full((), -1, jnp.int32),
        last_reset_ts=last_reset_ts,
    )
