"""STrack core — the paper's contribution as composable JAX modules."""
from .params import (  # noqa: F401
    NetworkSpec, STrackParams, DCQCNParams, RoCEParams,
    make_strack_params, make_dcqcn_params,
)
from .transport import (  # noqa: F401
    FlowState, TxPacket, init_flow, flow_on_sack, flow_next_packet,
    flow_on_timer, flow_done,
)
from .reliability import (  # noqa: F401
    SackMsg, ReceiverState, RelState, init_receiver, receiver_on_data,
    REORDER_WINDOW,
)
from .cc import CCState, init_cc, adjust_cwnd, update_achieved_bdp  # noqa: F401
from .lb import SprayState, init_spray, update_ecn_bitmap, choose_path  # noqa: F401
