"""Pure-Python reference implementation of the STrack transport (the oracle).

This mirrors Algorithms 1-4 and Section 3.3 of the paper exactly, in plain
Python with unbounded containers.  It is:
  * the oracle the JAX implementation (core/transport.py et al.) is
    property-tested against, and
  * the per-host engine used by the event-driven network simulator
    (sim/events.py) for the paper-table benchmarks.

Pseudocode reconciliation (documented deviation): the OCR'd Algorithm 2
listing flips the bitmap polarity relative to the prose ("STrack keeps a
simple bitmap for the entropies that have experienced ECN marks ... Next
non-marked entropy in a round robin manner is used").  We follow the prose:
``bitmap[p] == 1`` means path ``p`` saw an ECN mark (bad); CHOOSE_PATH
round-robins over unmarked entries, clearing the first skipped mark per
packet ("one packet only clears one bit").  ``next_path_id`` uses -1 as the
invalid sentinel so entropy 0 is usable.

Units: time in microseconds, sizes in bytes, cwnd in packets (float).
"""
from __future__ import annotations

import math
from typing import Optional

from .params import ACK_WIRE_BYTES, DCQCNParams, STrackParams

# ---------------------------------------------------------------------------
# Packets
# ---------------------------------------------------------------------------

DATA, SACK, PROBE, NACK, CNP = "data", "sack", "probe", "nack", "cnp"
ACK_SIZE = ACK_WIRE_BYTES  # bytes on the wire for SACK/NACK/CNP/probe


class Packet:
    """Wire packet. One object per packet in flight (event sim reuses it)."""

    __slots__ = (
        "kind", "flow", "psn", "size", "entropy", "ecn", "ts",
        "is_probe_reply", "epsn", "sack_base", "sack_bitmap", "bytes_recvd",
        "ooo_cnt", "src", "dst", "rtx",
        "_route", "_hop", "_ingress",  # used by sim/events.py routing
    )

    def __init__(self, kind, flow, psn, size, entropy, ts, src=-1, dst=-1):
        self.kind = kind
        self.flow = flow
        self.psn = psn
        self.size = size
        self.entropy = entropy
        self.ecn = False
        self.ts = ts
        self.is_probe_reply = False
        self.epsn = 0
        self.sack_base = 0
        self.sack_bitmap = 0
        self.bytes_recvd = 0
        self.ooo_cnt = 0
        self.src = src
        self.dst = dst
        self.rtx = False

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"Packet({self.kind} f={self.flow} psn={self.psn} "
                f"e={self.entropy} ecn={self.ecn})")


# ---------------------------------------------------------------------------
# Adaptive load balancing (Algorithm 2)
# ---------------------------------------------------------------------------

class SprayState:
    """STrack adaptive packet spray state: one bitmap + rr pointer + hint."""

    __slots__ = ("bitmap", "rr", "next_path_id", "last_reset_ts", "p")

    def __init__(self, p: STrackParams, now: float = 0.0):
        self.p = p
        self.bitmap = [0] * p.max_paths  # 1 = ECN-marked (bad) path
        self.rr = 0
        self.next_path_id = -1           # -1 = invalid
        self.last_reset_ts = now

    def update_ecn_bitmap(self, ecn: bool, path_id: int) -> None:
        if ecn:
            self.next_path_id = -1
            self.bitmap[path_id] = 1
        else:
            self.next_path_id = path_id
            self.bitmap[path_id] = 0

    def choose_path(self, cwnd_pkts: float, now: float) -> int:
        # Periodic staleness reset ("bitmap is reset after 1-2 RTTs").
        if now - self.last_reset_ts > self.p.bitmap_reset_rtts * self.p.base_rtt_us:
            self.bitmap = [0] * self.p.max_paths
            self.last_reset_ts = now
        if self.next_path_id >= 0:
            self.rr = self.next_path_id
            self.next_path_id = -1
            return self.rr
        paths = min(self.p.max_paths, int(2 * cwnd_pkts))
        paths = max(8, paths)
        self.rr = (self.rr + 1) % paths
        cleared = False
        scanned = 0
        while self.bitmap[self.rr] != 0:
            # one packet only clears one bit
            if not cleared:
                self.bitmap[self.rr] = 0
                cleared = True
            self.rr = (self.rr + 1) % paths
            scanned += 1
            if scanned > paths:  # all marked: bitmap now has one cleared bit
                break
        return self.rr


# ---------------------------------------------------------------------------
# Congestion control (Algorithms 3 & 4)
# ---------------------------------------------------------------------------

class CCState:
    """Sender congestion-control state: one window across all paths."""

    __slots__ = (
        "p", "cwnd", "base_rtt", "avg_delay", "last_decrease_ts",
        "last_selfai_ts", "achieved_bdp_pkts", "rx_count_bytes",
        "rxcount_clear_ts",
    )

    def __init__(self, p: STrackParams, now: float = 0.0):
        self.p = p
        self.cwnd = p.max_cwnd_pkts      # start at max (~BDP)
        self.base_rtt = p.base_rtt_us    # min observed RTT
        self.avg_delay = 0.0
        self.last_decrease_ts = now
        self.last_selfai_ts = now
        self.achieved_bdp_pkts = 0.0
        self.rx_count_bytes = 0.0
        self.rxcount_clear_ts = now

    # -- Algorithm 4 -------------------------------------------------------
    def update_achieved_bdp(self, acked_bytes: float, ack_for_probe: bool,
                            now: float) -> float:
        can_clear = (now - self.rxcount_clear_ts) > (
            self.base_rtt + self.p.target_qdelay_us)
        self.rx_count_bytes += 0.0 if ack_for_probe else acked_bytes
        if can_clear:
            self.achieved_bdp_pkts = self.rx_count_bytes / self.p.mtu_bytes
            self.rxcount_clear_ts = now
            self.rx_count_bytes = 0.0
        return self.achieved_bdp_pkts

    # -- Algorithm 3 -------------------------------------------------------
    def adjust_cwnd(self, ecn: bool, delay: float, achieved_bdp_pkts: float,
                    now: float) -> float:
        p = self.p
        can_decrease = now - self.last_decrease_ts > self.base_rtt
        can_fairness = now - self.last_selfai_ts > self.base_rtt
        self.avg_delay = self.avg_delay * (1 - p.ewma) + p.ewma * delay
        if not ecn and delay > p.target_qhigh_us:
            # queue drained behind a late packet: avoid starvation
            self.cwnd = self.cwnd + p.beta_pkts / self.cwnd
        elif not ecn and delay < p.target_qdelay_us:
            self.cwnd = self.cwnd + p.alpha_pkts_per_us * (
                p.target_qdelay_us - delay) / self.cwnd
        elif can_decrease and self.avg_delay > p.target_qdelay_us:
            if (delay > p.target_qhigh_us
                    and achieved_bdp_pkts < p.max_cwnd_pkts / 8):
                self.cwnd = achieved_bdp_pkts
                self.last_decrease_ts = now
            elif delay > p.target_qdelay_us:
                self.cwnd = self.cwnd * max(
                    1 - p.gamma * (self.avg_delay - p.target_qdelay_us)
                    / self.avg_delay, 0.5)
                self.last_decrease_ts = now
        if can_fairness:
            self.cwnd = self.cwnd + p.eta_pkts
            self.last_selfai_ts = now
        self.cwnd = min(max(self.cwnd, p.min_cwnd_pkts), p.max_cwnd_pkts)
        return self.cwnd


# ---------------------------------------------------------------------------
# STrack receiver (Section 3.3.1)
# ---------------------------------------------------------------------------

class STrackReceiver:
    """Tracks arrivals past EPSN; coalesces SACKs; answers probes."""

    __slots__ = ("p", "epsn", "pending", "bytes_recvd", "bytes_since_sack",
                 "lpsn_since_sack", "total_pkts")

    def __init__(self, p: STrackParams, total_pkts: int):
        self.p = p
        self.epsn = 0
        self.pending: set[int] = set()   # received psns > epsn
        self.bytes_recvd = 0.0           # deduplicated
        self.bytes_since_sack = 0.0
        self.lpsn_since_sack: Optional[int] = None
        self.total_pkts = total_pkts

    def _mk_sack(self, pkt: Packet, now: float, probe_reply: bool) -> Packet:
        bits = self.p.sack_bitmap_bits
        # Segment (relative to EPSN) containing the lowest PSN since last SACK.
        lpsn = self.lpsn_since_sack if self.lpsn_since_sack is not None else self.epsn
        lpsn = max(lpsn, self.epsn)
        seg = (lpsn - self.epsn) // bits
        base = self.epsn + seg * bits
        bitmap = 0
        for i in range(bits):
            if (base + i) < self.epsn or (base + i) in self.pending:
                bitmap |= (1 << i)
        s = Packet(SACK, pkt.flow, pkt.psn, ACK_SIZE, pkt.entropy, pkt.ts,
                   src=pkt.dst, dst=pkt.src)
        s.ecn = pkt.ecn
        s.is_probe_reply = probe_reply
        s.epsn = self.epsn
        s.sack_base = base
        s.sack_bitmap = bitmap
        s.bytes_recvd = self.bytes_recvd
        s.ooo_cnt = len(self.pending)
        self.bytes_since_sack = 0.0
        self.lpsn_since_sack = None
        return s

    def on_data(self, pkt: Packet, now: float) -> Optional[Packet]:
        if pkt.kind == PROBE:
            return self._mk_sack(pkt, now, probe_reply=True)
        old_epsn = self.epsn
        dup = pkt.psn < self.epsn or pkt.psn in self.pending
        if not dup:
            self.bytes_recvd += pkt.size
            self.bytes_since_sack += pkt.size
            self.pending.add(pkt.psn)
            while self.epsn in self.pending:
                self.pending.remove(self.epsn)
                self.epsn += 1
            if self.lpsn_since_sack is None or pkt.psn < self.lpsn_since_sack:
                self.lpsn_since_sack = pkt.psn
        if (self.bytes_since_sack >= self.p.ack_coalesce_bytes
                or (not dup and pkt.psn == old_epsn)
                or self.epsn >= self.total_pkts):
            return self._mk_sack(pkt, now, probe_reply=False)
        return None


# ---------------------------------------------------------------------------
# STrack sender (Algorithm 1 + Section 3.3.2)
# ---------------------------------------------------------------------------

class STrackSender:
    """Window-clocked multipath sender with selective retransmission."""

    __slots__ = (
        "p", "flow", "total_pkts", "msg_bytes", "cc", "spray",
        "psn_next", "bytes_sent", "bytes_recvd_seen", "bytes_claimed_rtx",
        "epsn", "sacked", "claimed", "rtx_queue",
        "in_recovery", "recover_high", "probe_deadline", "rto_deadline",
        "probes_sent", "done_ts", "start_ts", "rtt_samples", "retransmits",
        "spurious_rtx",
    )

    def __init__(self, p: STrackParams, flow: int, msg_bytes: float,
                 now: float = 0.0):
        self.p = p
        self.flow = flow
        self.msg_bytes = msg_bytes
        self.total_pkts = max(1, math.ceil(msg_bytes / p.mtu_bytes))
        self.cc = CCState(p, now)
        self.spray = SprayState(p, now)
        self.psn_next = 0
        self.bytes_sent = 0.0
        self.bytes_recvd_seen = 0.0     # latest bytes_recvd echoed by receiver
        self.bytes_claimed_rtx = 0.0
        self.epsn = 0                   # receiver's cumulative ack point
        self.sacked: set[int] = set()   # selectively acked psns >= epsn
        self.claimed: set[int] = set()  # psns declared lost, not yet re-sent
        self.rtx_queue: list[int] = []
        self.in_recovery = False
        self.recover_high = -1
        self.probe_deadline = now + p.probe_rtts * p.base_rtt_us
        self.rto_deadline = now + p.rto_us
        self.probes_sent = 0
        self.done_ts: Optional[float] = None
        self.start_ts = now
        self.rtt_samples: list[float] = []
        self.retransmits = 0
        self.spurious_rtx = 0

    # -- helpers ------------------------------------------------------------
    def pkt_size(self, psn: int) -> int:
        if psn == self.total_pkts - 1:
            rem = int(self.msg_bytes - (self.total_pkts - 1) * self.p.mtu_bytes)
            return max(1, rem)
        return self.p.mtu_bytes

    @property
    def inflight_bytes(self) -> float:
        return self.bytes_sent - self.bytes_recvd_seen - self.bytes_claimed_rtx

    def done(self) -> bool:
        return self.epsn >= self.total_pkts

    def can_send(self) -> bool:
        if self.done():
            return False
        has_data = bool(self.rtx_queue) or self.psn_next < self.total_pkts
        return has_data and (
            self.inflight_bytes < self.cc.cwnd * self.p.mtu_bytes)

    # -- transmission -------------------------------------------------------
    def next_packet(self, now: float) -> Optional[Packet]:
        if not self.can_send():
            return None
        rtx = False
        if self.rtx_queue:
            psn = self.rtx_queue.pop(0)
            if psn < self.epsn or psn in self.sacked:
                return self.next_packet(now)   # became acked meanwhile
            self.claimed.discard(psn)
            rtx = True
            self.retransmits += 1
        else:
            psn = self.psn_next
            self.psn_next += 1
        size = self.pkt_size(psn)
        entropy = self.spray.choose_path(self.cc.cwnd, now)
        pkt = Packet(DATA, self.flow, psn, size, entropy, now)
        pkt.rtx = rtx
        self.bytes_sent += size
        return pkt

    def make_probe(self, now: float) -> Packet:
        self.probes_sent += 1
        self.probe_deadline = now + self.p.probe_rtts * self.p.base_rtt_us
        entropy = self.spray.choose_path(self.cc.cwnd, now)
        return Packet(PROBE, self.flow, self.epsn, ACK_SIZE, entropy, now)

    # -- loss declaration ---------------------------------------------------
    def _declare_lost(self, psns) -> None:
        for psn in psns:
            if psn in self.claimed or psn in self.sacked or psn < self.epsn:
                continue
            self.claimed.add(psn)
            self.bytes_claimed_rtx += self.pkt_size(psn)
            self.rtx_queue.append(psn)
        self.rtx_queue.sort()

    def _enter_recovery(self, high: int) -> None:
        self.in_recovery = True
        self.recover_high = max(self.recover_high, high)
        lost = [psn for psn in range(self.epsn, self.recover_high)
                if psn not in self.sacked]
        self._declare_lost(lost)

    # -- Algorithm 1: on_receiving_ack ---------------------------------------
    def on_sack(self, sack: Packet, now: float) -> None:
        p = self.p
        measured_rtt = now - sack.ts
        self.rtt_samples.append(measured_rtt)
        if measured_rtt < self.cc.base_rtt:
            self.cc.base_rtt = measured_rtt
        qdelay = measured_rtt - self.cc.base_rtt
        self.probe_deadline = now + p.probe_rtts * p.base_rtt_us

        # Probe-based loss detection (Algo 1 line 13).
        if (sack.is_probe_reply and qdelay < 2 * p.base_rtt_us
                and self.cc.achieved_bdp_pkts == 0.0
                and not self.done()):
            self._enter_recovery(self.psn_next)

        if not sack.is_probe_reply:
            self.spray.update_ecn_bitmap(sack.ecn, sack.entropy)

        # Cumulative + selective ack bookkeeping.
        old_epsn = self.epsn
        if sack.epsn > self.epsn:
            self.epsn = sack.epsn
            self.rto_deadline = now + p.rto_us
            self.sacked = {s for s in self.sacked if s >= self.epsn}
            for psn in list(self.claimed):
                if psn < self.epsn:
                    # acked before we retransmitted: un-claim
                    self.claimed.discard(psn)
                    self.bytes_claimed_rtx -= self.pkt_size(psn)
                    self.spurious_rtx += 1
            self.rtx_queue = [x for x in self.rtx_queue if x >= self.epsn]
        for i in range(p.sack_bitmap_bits):
            if sack.sack_bitmap & (1 << i):
                psn = sack.sack_base + i
                if psn >= self.epsn and psn not in self.sacked:
                    self.sacked.add(psn)
                    if psn in self.claimed:
                        self.claimed.discard(psn)
                        self.bytes_claimed_rtx -= self.pkt_size(psn)
                        self.spurious_rtx += 1
                        if psn in self.rtx_queue:
                            self.rtx_queue.remove(psn)

        acked_bytes = max(0.0, sack.bytes_recvd - self.bytes_recvd_seen)
        self.bytes_recvd_seen = max(self.bytes_recvd_seen, sack.bytes_recvd)

        achieved = self.cc.update_achieved_bdp(
            acked_bytes, sack.is_probe_reply, now)
        self.cc.adjust_cwnd(sack.ecn, qdelay, achieved, now)

        # OOO-based loss detection (Section 3.3.2).
        thresh = max(self.cc.cwnd, float(p.min_ooo_threshold))
        if sack.ooo_cnt > thresh:
            high = max(self.sacked) if self.sacked else self.epsn
            self._enter_recovery(high)

        # Recovery exit: everything up to recover_high acked.
        if self.in_recovery and self.epsn >= self.recover_high:
            self.in_recovery = False
            self.recover_high = -1

        if self.done() and self.done_ts is None:
            self.done_ts = now

    # -- timers ---------------------------------------------------------------
    def next_timer_deadline(self) -> float:
        if self.done():
            return math.inf
        return min(self.probe_deadline, self.rto_deadline)

    def on_timer(self, now: float) -> Optional[Packet]:
        """Fire whichever timer expired; may return a probe packet to send."""
        if self.done():
            return None
        if now >= self.rto_deadline:
            # Timeout: all unacked packets declared lost.
            self.rto_deadline = now + self.p.rto_us
            self._enter_recovery(self.psn_next)
            return None
        if now >= self.probe_deadline:
            return self.make_probe(now)
        return None


# ---------------------------------------------------------------------------
# RoCEv2 baseline: DCQCN + go-back-N (PFC lives in the switch model)
# ---------------------------------------------------------------------------

class DCQCNState:
    """DCQCN rate state (Zhu et al., SIGCOMM'15)."""

    __slots__ = ("p", "rate", "target", "alpha", "t_stage", "b_stage",
                 "bytes_ctr", "last_rate_ts", "last_alpha_ts", "max_rate",
                 "last_cut_ts")

    def __init__(self, p: DCQCNParams, line_rate: float, now: float = 0.0):
        self.p = p
        self.rate = line_rate
        self.target = line_rate
        self.max_rate = line_rate
        self.alpha = 1.0
        self.t_stage = 0
        self.b_stage = 0
        self.bytes_ctr = 0.0
        self.last_rate_ts = now
        self.last_alpha_ts = now
        self.last_cut_ts = now

    def on_cnp(self, now: float) -> None:
        self.target = self.rate
        self.rate = max(self.rate * (1 - self.alpha / 2), self.p.min_rate_Bpus)
        self.alpha = (1 - self.p.g) * self.alpha + self.p.g
        self.t_stage = 0
        self.b_stage = 0
        self.bytes_ctr = 0.0
        self.last_rate_ts = now
        self.last_alpha_ts = now
        self.last_cut_ts = now

    def _increase(self) -> None:
        # DCQCN phases (Zhu'15): hyper when BOTH counters passed F,
        # additive when EITHER did, else fast recovery.
        if min(self.t_stage, self.b_stage) > self.p.f_fast_recovery:
            self.target = min(self.target + self.p.hai_mbps, self.max_rate)
        elif max(self.t_stage, self.b_stage) > self.p.f_fast_recovery:
            self.target = min(self.target + self.p.rai_mbps, self.max_rate)
        # fast recovery: rate -> (rate+target)/2, target unchanged
        self.rate = min((self.rate + self.target) / 2, self.max_rate)

    def on_bytes_sent(self, nbytes: float) -> None:
        self.bytes_ctr += nbytes
        if self.bytes_ctr >= self.p.byte_counter:
            self.bytes_ctr = 0.0
            self.b_stage += 1
            self._increase()

    def on_timer(self, now: float) -> None:
        if now - self.last_alpha_ts >= self.p.alpha_timer_us:
            self.alpha = (1 - self.p.g) * self.alpha
            self.last_alpha_ts = now
        if now - self.last_rate_ts >= self.p.rate_timer_us:
            self.t_stage += 1
            self.last_rate_ts = now
            self._increase()


class RoCESender:
    """Go-back-N sender paced by DCQCN. Single path (fixed entropy)."""

    __slots__ = ("p", "dcqcn", "flow", "total_pkts", "msg_bytes", "mtu",
                 "snd_una", "psn_next", "entropy", "next_send_ts",
                 "rto_deadline", "done_ts", "start_ts", "rto_us", "window_pkts",
                 "retransmits")

    def __init__(self, dcqcn_p: DCQCNParams, flow: int, msg_bytes: float,
                 mtu: int, line_rate: float, entropy: int, rto_us: float,
                 window_bdp_pkts: float, now: float = 0.0):
        self.p = dcqcn_p
        self.dcqcn = DCQCNState(dcqcn_p, line_rate, now)
        self.flow = flow
        self.msg_bytes = msg_bytes
        self.mtu = mtu
        self.total_pkts = max(1, math.ceil(msg_bytes / mtu))
        self.snd_una = 0
        self.psn_next = 0
        self.entropy = entropy
        self.next_send_ts = now
        self.rto_us = rto_us
        self.rto_deadline = now + rto_us
        self.done_ts: Optional[float] = None
        self.start_ts = now
        self.window_pkts = window_bdp_pkts  # static window (lossless net)
        self.retransmits = 0

    def pkt_size(self, psn: int) -> int:
        if psn == self.total_pkts - 1:
            rem = int(self.msg_bytes - (self.total_pkts - 1) * self.mtu)
            return max(1, rem)
        return self.mtu

    def done(self) -> bool:
        return self.snd_una >= self.total_pkts

    def can_send(self, now: float) -> bool:
        return (not self.done() and self.psn_next < self.total_pkts
                and now >= self.next_send_ts
                and (self.psn_next - self.snd_una) < self.window_pkts)

    def next_packet(self, now: float) -> Optional[Packet]:
        if not self.can_send(now):
            return None
        psn = self.psn_next
        self.psn_next += 1
        size = self.pkt_size(psn)
        pkt = Packet(DATA, self.flow, psn, size, self.entropy, now)
        self.dcqcn.on_bytes_sent(size)
        # pace at DCQCN rate
        self.next_send_ts = now + size / max(self.dcqcn.rate, 1e-9)
        return pkt

    def on_ack(self, ack: Packet, now: float) -> None:
        if ack.kind == CNP:
            self.dcqcn.on_cnp(now)
            return
        if ack.kind == NACK:
            # go-back-N: rewind to receiver's expected psn
            if ack.epsn > self.snd_una:
                self.snd_una = ack.epsn
            if self.psn_next > ack.epsn:
                self.retransmits += self.psn_next - ack.epsn
            self.psn_next = max(self.snd_una, ack.epsn)
            self.rto_deadline = now + self.rto_us
            return
        if ack.epsn > self.snd_una:
            self.snd_una = ack.epsn
            self.rto_deadline = now + self.rto_us
        if self.done() and self.done_ts is None:
            self.done_ts = now

    def next_timer_deadline(self) -> float:
        if self.done():
            return math.inf
        # NB: next_send_ts (pacing) is the NIC pump's responsibility, not a
        # timer — mixing them causes same-instant timer/pump livelock.
        return min(self.rto_deadline,
                   self.dcqcn.last_alpha_ts + self.p.alpha_timer_us,
                   self.dcqcn.last_rate_ts + self.p.rate_timer_us)

    def on_timer(self, now: float) -> None:
        self.dcqcn.on_timer(now)
        if now >= self.rto_deadline and not self.done():
            self.psn_next = self.snd_una  # go-back-N from snd_una
            self.rto_deadline = now + self.rto_us


class RoCEReceiver:
    """In-order-only receiver: acks cumulative EPSN, NACKs on gaps, CNPs on ECN."""

    __slots__ = ("epsn", "total_pkts", "coalesce", "since_ack", "last_cnp_ts",
                 "cnp_interval", "bytes_recvd")

    def __init__(self, total_pkts: int, coalesce_pkts: int,
                 cnp_interval_us: float):
        self.epsn = 0
        self.total_pkts = total_pkts
        self.coalesce = coalesce_pkts
        self.since_ack = 0
        self.last_cnp_ts = -1e18
        self.cnp_interval = cnp_interval_us
        self.bytes_recvd = 0.0

    def on_data(self, pkt: Packet, now: float) -> list[Packet]:
        out: list[Packet] = []
        if pkt.ecn and now - self.last_cnp_ts >= self.cnp_interval:
            cnp = Packet(CNP, pkt.flow, 0, ACK_SIZE, pkt.entropy, pkt.ts,
                         src=pkt.dst, dst=pkt.src)
            self.last_cnp_ts = now
            out.append(cnp)
        if pkt.psn == self.epsn:
            self.epsn += 1
            self.bytes_recvd += pkt.size
            self.since_ack += 1
            if self.since_ack >= self.coalesce or self.epsn >= self.total_pkts:
                ack = Packet(SACK, pkt.flow, pkt.psn, ACK_SIZE, pkt.entropy,
                             pkt.ts, src=pkt.dst, dst=pkt.src)
                ack.epsn = self.epsn
                ack.bytes_recvd = self.bytes_recvd
                self.since_ack = 0
                out.append(ack)
        elif pkt.psn > self.epsn:
            # out-of-order: go-back-N NACK with expected psn
            nack = Packet(NACK, pkt.flow, pkt.psn, ACK_SIZE, pkt.entropy,
                          pkt.ts, src=pkt.dst, dst=pkt.src)
            nack.epsn = self.epsn
            out.append(nack)
        else:
            # duplicate of already-delivered packet: re-ack
            ack = Packet(SACK, pkt.flow, pkt.psn, ACK_SIZE, pkt.entropy,
                         pkt.ts, src=pkt.dst, dst=pkt.src)
            ack.epsn = self.epsn
            ack.bytes_recvd = self.bytes_recvd
            out.append(ack)
        return out
