"""STrack / RoCEv2 transport parameters.

Table 1 of the paper, plus network-derived quantities. All times are in
MICROSECONDS and all sizes in BYTES unless a field name says otherwise.
The congestion window is kept in PACKETS (floats) — the paper's constants
are specified in MTU units scaled by ``bdp_sf`` so packet units keep the
algebra identical to Table 1.

Reference network of Table 1: 100 Gbps links, 12 us network base RTT.
``bdp_sf`` and ``delay_sf`` rescale the constants to any link speed / RTT.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

GBPS = 1e9 / 8 / 1e6  # bytes per microsecond for 1 Gbps

#: Wire size of SACK / NACK / CNP / probe packets (bytes).  Shared by the
#: event oracle (``core.ref.ACK_SIZE``) and the fabric's reverse-path and
#: PFC byte accounting.
ACK_WIRE_BYTES = 64

#: Store-and-forward hops of one direction of a cross-ToR path on the
#: 2-tier Clos: host NIC -> ToR uplink -> spine downlink -> host downlink.
#: The ACK path traverses the same count in reverse.
CLOS_HOPS = 4


def bytes_per_us(gbps: float) -> float:
    """Link rate in bytes/us for a given Gbps figure."""
    return gbps * GBPS


@dataclasses.dataclass(frozen=True)
class NetworkSpec:
    """Physical network the transport runs over."""

    link_gbps: float = 400.0
    base_rtt_us: float = 8.0      # network-wide base RTT (paper: 8 us)
    mtu_bytes: int = 4096
    # Switch config (paper Section 4.1).
    ecn_kmin_frac: float = 0.25   # K_min = 25% BDP
    ecn_kmax_frac: float = 0.75   # K_max = 75% BDP
    drop_frac: float = 5.0        # drop when queue exceeds 5 BDP
    # Per-link propagation delay (us).  None derives it from base_rtt_us so
    # that an uncongested cross-ToR data+ACK round trip (CLOS_HOPS
    # store-and-forward hops each way, MTU data out / ACK_WIRE_BYTES back)
    # realizes exactly base_rtt_us — the shared per-hop delay model of the
    # jitted fabric AND the event oracle (apples-to-apples parity).
    hop_prop_us: Optional[float] = None

    @property
    def rate_Bpus(self) -> float:
        return bytes_per_us(self.link_gbps)

    @property
    def bdp_bytes(self) -> float:
        """Bandwidth-delay product (400 Gbps x 8 us = 400 KB in the paper)."""
        return self.rate_Bpus * self.base_rtt_us

    @property
    def bdp_pkts(self) -> float:
        return self.bdp_bytes / self.mtu_bytes

    @property
    def ecn_kmin_bytes(self) -> float:
        return self.ecn_kmin_frac * self.bdp_bytes

    @property
    def ecn_kmax_bytes(self) -> float:
        return self.ecn_kmax_frac * self.bdp_bytes

    @property
    def drop_bytes(self) -> float:
        return self.drop_frac * self.bdp_bytes

    @property
    def mtu_serialize_us(self) -> float:
        return self.mtu_bytes / self.rate_Bpus

    @property
    def ack_serialize_us(self) -> float:
        return ACK_WIRE_BYTES / self.rate_Bpus

    @property
    def hop_prop_effective_us(self) -> float:
        """Per-link propagation delay: ``hop_prop_us`` when set, else
        derived so base RTT = CLOS_HOPS * (mtu_ser + prop) forward plus
        CLOS_HOPS * (ack_ser + prop) back.  Clipped at 0 when base_rtt_us
        is below the serialization floor (the realized RTT is then the
        floor itself)."""
        if self.hop_prop_us is not None:
            return self.hop_prop_us
        ser = CLOS_HOPS * (self.mtu_serialize_us + self.ack_serialize_us)
        return max(0.0, (self.base_rtt_us - ser) / (2 * CLOS_HOPS))


# Table 1 reference point: constants are specified for 100 Gbps / 12 us.
_REF_RATE_BPUS = bytes_per_us(100.0)
_REF_RTT_US = 12.0


@dataclasses.dataclass(frozen=True)
class STrackParams:
    """Table 1 of the paper, in packet (MTU) units.

    cwnd is maintained in packets; Table 1's byte-valued constants are
    divided by MTU so e.g. ``beta = 5 * bdp_sf`` packets.
    """

    base_rtt_us: float            # network base RTT
    target_qdelay_us: float       # target queuing delay == net base RTT
    target_qhigh_us: float        # 3 * target_Qdelay
    ewma: float                   # RTT averaging weight
    bdp_sf: float                 # BDP / (100Gbps * 12us)
    delay_sf: float               # base_rtt / 12us
    beta_pkts: float              # additive increase: 5 * MTU * bdp_sf (in pkts: 5*bdp_sf)
    eta_pkts: float               # fairness shuffle: 0.15 * MTU * bdp_sf
    alpha_pkts_per_us: float      # RTT gain: 4.0 * bdp_sf * delay_sf * MTU / base_rtt
    gamma: float                  # multiplicative decrease = 0.8
    max_cwnd_pkts: float          # roughly the BDP
    min_cwnd_pkts: float          # floor (fractional windows allowed: paper's 1.3 pkt point)
    max_paths: int                # entropy space for spray (paper: 256)
    min_ooo_threshold: int        # OOO loss-detection floor (paper: 5)
    probe_rtts: float             # probe after n=3 base RTTs of ACK silence
    rto_us: float                 # retransmission timeout (hundreds of us)
    bitmap_reset_rtts: float      # spray bitmap reset cadence (1-2 RTTs)
    sack_bitmap_bits: int         # bits carried per SACK (Fig 7: 64)
    rcv_bitmap_bits: int          # receiver reorder bitmap size (e.g. 256)
    ack_coalesce_bytes: float     # SACK emitted every this many received bytes
    mtu_bytes: int


def make_strack_params(
    net: NetworkSpec,
    *,
    max_paths: int = 256,
    min_ooo_threshold: int = 5,
    probe_rtts: float = 3.0,
    rto_us: float = 400.0,
    bitmap_reset_rtts: float = 2.0,
    sack_bitmap_bits: int = 64,
    rcv_bitmap_bits: int = 256,
    ack_coalesce_pkts: float = 2.0,
    max_cwnd_bdp_frac: float = 1.0,
) -> STrackParams:
    """Instantiate Table 1 for a given network (scaling via bdp_sf/delay_sf)."""
    bdp_sf = net.bdp_bytes / (_REF_RATE_BPUS * _REF_RTT_US)
    delay_sf = net.base_rtt_us / _REF_RTT_US
    target_qdelay = net.base_rtt_us  # "target_Qdelay = net_base_rtt"
    return STrackParams(
        base_rtt_us=net.base_rtt_us,
        target_qdelay_us=target_qdelay,
        target_qhigh_us=3.0 * target_qdelay,
        ewma=0.125,
        bdp_sf=bdp_sf,
        delay_sf=delay_sf,
        beta_pkts=5.0 * bdp_sf,
        eta_pkts=0.15 * bdp_sf,
        # Table 1: alpha = 4.0 * bdp_sf * delay_sf * MTU / base_rtt (bytes/us)
        # -> packets/us after the MTU division.
        alpha_pkts_per_us=4.0 * bdp_sf * delay_sf / net.base_rtt_us,
        gamma=0.8,
        max_cwnd_pkts=max_cwnd_bdp_frac * net.bdp_pkts,
        min_cwnd_pkts=1.0 / 8.0,
        max_paths=max_paths,
        min_ooo_threshold=min_ooo_threshold,
        probe_rtts=probe_rtts,
        rto_us=rto_us,
        bitmap_reset_rtts=bitmap_reset_rtts,
        sack_bitmap_bits=sack_bitmap_bits,
        rcv_bitmap_bits=rcv_bitmap_bits,
        ack_coalesce_bytes=ack_coalesce_pkts * net.mtu_bytes,
        mtu_bytes=net.mtu_bytes,
    )


@dataclasses.dataclass(frozen=True)
class DCQCNParams:
    """DCQCN (RoCEv2's congestion control) constants, per Zhu et al. 2015.

    Rate-based: alpha ewma'd from CNP arrivals; rate cut R = R*(1-alpha/2)
    on CNP; byte-counter/timer driven recovery through fast-recovery,
    additive-increase and hyper-increase phases.
    """

    g: float = 1.0 / 256.0        # alpha ewma gain
    alpha_timer_us: float = 55.0  # alpha update interval absent CNPs
    rate_timer_us: float = 55.0   # rate increase timer (paper uses 55us)
    byte_counter: float = 10.0 * 1024 * 1024  # 10MB byte counter stage
    rai_mbps: float = 40.0 * 125  # additive increase step, bytes/us (40 Mbps=5 B/us)*... see below
    hai_mbps: float = 400.0 * 125
    f_fast_recovery: int = 5      # stages of fast recovery before AI
    min_rate_Bpus: float = 1.25   # 10 Mbps floor
    cnp_interval_us: float = 50.0  # receiver emits at most one CNP per 50us per flow

    # NOTE: rai/hai above are stored in bytes/us: 40 Mbps = 5 B/us; the
    # constructor-level *_mbps naming retains the DCQCN convention.


def make_dcqcn_params(net: NetworkSpec) -> DCQCNParams:
    # Scale increase steps with link speed ("optimized RoCEv2 setup",
    # paper Section 4.1 — a strong baseline recovers promptly at 400G+).
    rai = bytes_per_us(net.link_gbps) / 500.0    # 400G -> 100 B/us steps
    hai = 10.0 * rai
    return DCQCNParams(rai_mbps=rai, hai_mbps=hai)


@dataclasses.dataclass(frozen=True)
class RoCEParams:
    """RoCEv2 transport config: go-back-N + PFC (lossless) + DCQCN."""

    dcqcn: DCQCNParams = dataclasses.field(default_factory=DCQCNParams)
    qps_per_conn: int = 1          # entropy count (paper compares 1 and 4)
    ack_coalesce_pkts: int = 2
    rto_us: float = 400.0
    ecn_kmin_bdp: float = 1.0      # "ECN threshold to one BDP for DCQCN"
    ecn_kmax_bdp: float = 1.0
    pfc_xoff_bytes: float = 512 * 1024.0   # per-ingress pause threshold
    pfc_xon_frac: float = 0.5


def make_roce_params(net: NetworkSpec, *, qps_per_conn: int = 1) -> RoCEParams:
    """RoCEv2 baseline config scaled to ``net`` (DCQCN steps follow rate)."""
    return RoCEParams(dcqcn=make_dcqcn_params(net), qps_per_conn=qps_per_conn)
