"""Algorithms 3 & 4 — STrack congestion control, as pure JAX functions.

One congestion window governs all paths.  ECN steers path choice (lb.py);
RTT — a multi-bit signal — steers the window.  ``achievedBDP`` (delivered
bytes per base RTT) provides O(1) convergence under heavy incast.

Semantics match ``core/ref.py`` (property-tested in tests/test_core_vs_ref).
cwnd is in packets (MTU units); time in microseconds.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .params import STrackParams


class CCState(NamedTuple):
    cwnd: jax.Array              # f32, packets
    base_rtt: jax.Array          # f32, us (min observed)
    avg_delay: jax.Array         # f32, us (ewma of queuing delay)
    last_decrease_ts: jax.Array  # f32, us
    last_selfai_ts: jax.Array    # f32, us
    achieved_bdp_pkts: jax.Array  # f32, packets
    rx_count_bytes: jax.Array    # f32, bytes
    rxcount_clear_ts: jax.Array  # f32, us


def init_cc(p: STrackParams, now: float = 0.0) -> CCState:
    f = lambda v: jnp.full((), v, jnp.float32)
    return CCState(
        cwnd=f(p.max_cwnd_pkts),
        base_rtt=f(p.base_rtt_us),
        avg_delay=f(0.0),
        last_decrease_ts=f(now),
        last_selfai_ts=f(now),
        achieved_bdp_pkts=f(0.0),
        rx_count_bytes=f(0.0),
        rxcount_clear_ts=f(now),
    )


def update_achieved_bdp(s: CCState, p: STrackParams, acked_bytes: jax.Array,
                        ack_for_probe: jax.Array, now: jax.Array) -> CCState:
    """Algorithm 4: delivered-bytes window over (base_rtt + target_Qdelay)."""
    now = jnp.asarray(now, jnp.float32)
    can_clear = (now - s.rxcount_clear_ts) > (s.base_rtt + p.target_qdelay_us)
    rx = s.rx_count_bytes + jnp.where(ack_for_probe, 0.0, acked_bytes)
    achieved = jnp.where(can_clear, rx / p.mtu_bytes, s.achieved_bdp_pkts)
    return s._replace(
        achieved_bdp_pkts=achieved,
        rx_count_bytes=jnp.where(can_clear, 0.0, rx),
        rxcount_clear_ts=jnp.where(can_clear, now, s.rxcount_clear_ts),
    )


def adjust_cwnd(s: CCState, p: STrackParams, ecn: jax.Array,
                delay: jax.Array, now: jax.Array) -> CCState:
    """Algorithm 3: the four-quadrant window update."""
    ecn = jnp.asarray(ecn, bool)
    delay = jnp.asarray(delay, jnp.float32)
    now = jnp.asarray(now, jnp.float32)
    achieved = s.achieved_bdp_pkts

    can_decrease = (now - s.last_decrease_ts) > s.base_rtt
    can_fairness = (now - s.last_selfai_ts) > s.base_rtt
    avg_delay = s.avg_delay * (1 - p.ewma) + p.ewma * delay

    # Branch 1: !ecn and delay > target_Qhigh  (queue drained; avoid starving)
    b1 = (~ecn) & (delay > p.target_qhigh_us)
    # Branch 2 (elif): !ecn and delay < target_Qdelay (proportional increase)
    b2 = (~b1) & (~ecn) & (delay < p.target_qdelay_us)
    # Branch 3 (elif): can_decrease and avg_delay > target_Qdelay
    b3 = (~b1) & (~b2) & can_decrease & (avg_delay > p.target_qdelay_us)
    #   3a: delay > Qhigh and achievedBDP < max_cwnd/8 -> jump to achievedBDP
    b3a = b3 & (delay > p.target_qhigh_us) & (achieved < p.max_cwnd_pkts / 8)
    #   3b (elif): delay > Qdelay -> multiplicative decrease
    b3b = b3 & (~b3a) & (delay > p.target_qdelay_us)

    cwnd = s.cwnd
    cwnd = jnp.where(b1, cwnd + p.beta_pkts / cwnd, cwnd)
    cwnd = jnp.where(
        b2, cwnd + p.alpha_pkts_per_us * (p.target_qdelay_us - delay) / cwnd,
        cwnd)
    cwnd = jnp.where(b3a, achieved, cwnd)
    md = s.cwnd * jnp.maximum(
        1 - p.gamma * (avg_delay - p.target_qdelay_us)
        / jnp.maximum(avg_delay, 1e-9), 0.5)
    cwnd = jnp.where(b3b, md, cwnd)
    last_decrease_ts = jnp.where(b3a | b3b, now, s.last_decrease_ts)

    cwnd = jnp.where(can_fairness, cwnd + p.eta_pkts, cwnd)
    last_selfai_ts = jnp.where(can_fairness, now, s.last_selfai_ts)

    cwnd = jnp.clip(cwnd, p.min_cwnd_pkts, p.max_cwnd_pkts)
    return s._replace(cwnd=cwnd, avg_delay=avg_delay,
                      last_decrease_ts=last_decrease_ts,
                      last_selfai_ts=last_selfai_ts)


def on_ack_cc(s: CCState, p: STrackParams, ecn: jax.Array,
              measured_rtt: jax.Array, acked_bytes: jax.Array,
              ack_for_probe: jax.Array, now: jax.Array) -> CCState:
    """Algorithm 1's CC portion: base-RTT tracking + Algo 4 + Algo 3."""
    measured_rtt = jnp.asarray(measured_rtt, jnp.float32)
    base_rtt = jnp.minimum(s.base_rtt, measured_rtt)
    qdelay = measured_rtt - base_rtt
    s = s._replace(base_rtt=base_rtt)
    s = update_achieved_bdp(s, p, acked_bytes, ack_for_probe, now)
    return adjust_cwnd(s, p, ecn, qdelay, now)
