"""Version compatibility shims for the installed JAX.

The repo targets both older (0.4.3x) and newer JAX releases across three
API moves:

* ``jax.sharding.AxisType`` + ``jax.make_mesh(..., axis_types=...)`` only
  exist in newer JAX; older releases take no ``axis_types`` argument.
* ``jax.shard_map`` was promoted from ``jax.experimental.shard_map``.
* its replication-check kwarg was renamed ``check_rep`` -> ``check_vma``.

Everything here degrades to the older spelling when the newer one is
missing, so callers can use one code path.
"""
from __future__ import annotations

import inspect

import jax


def mesh_axis_types_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (AxisType.Auto,) * n}`` when supported, else ``{}``."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    try:
        params = inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):  # pragma: no cover - exotic builds
        return {}
    if "axis_types" not in params:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    shape, axes = tuple(shape), tuple(axes)
    return jax.make_mesh(shape, axes, **mesh_axis_types_kwargs(len(axes)))


def _resolve_shard_map():
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map as exp_fn
    return exp_fn


_SHARD_MAP = _resolve_shard_map()
_SHARD_MAP_PARAMS = frozenset(inspect.signature(_SHARD_MAP).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """``jax.shard_map`` across the check_vma/check_rep rename."""
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if "check_vma" in _SHARD_MAP_PARAMS:
        kw["check_vma"] = check_vma
    elif "check_rep" in _SHARD_MAP_PARAMS:
        kw["check_rep"] = check_vma
    return _SHARD_MAP(f, **kw)
