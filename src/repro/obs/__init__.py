"""Observatory: zero-dependency fabric telemetry.

The soak driver (``repro.sim.traffic.soak``) folds each epoch's fabric
counters — queue depth max/p99, PFC pauses, ECN marks, drops,
retransmits, per-tenant FCT percentiles — into a
:class:`~repro.obs.metrics.MetricsRegistry`, renders it in Prometheus
text exposition format, and dumps it to a ``.prom`` file that
``repro.obs.exporter`` can serve over HTTP with nothing but the stdlib.
``repro.obs.trend`` keeps the cross-PR benchmark trajectory
(``BENCH_history.jsonl``) and gates regressions against the best run in
history, not just the last one.

Everything here is pure stdlib: no prometheus_client, no jax.
"""
from .metrics import (MetricsRegistry, parse_prometheus,  # noqa: F401
                      render_prometheus)
from .trend import append_run, gate_and_append, load_history, \
    trend_problems  # noqa: F401
