"""Cross-PR benchmark trajectory: BENCH_history.jsonl append + gate.

``BENCH_fabric.json`` is a snapshot that each ``make bench`` overwrites,
so its regression gate only ever sees the previous run.  This module
keeps the whole trajectory instead: one JSON line per bench run
(timestamp + the per-scenario warm warp ticks/sec), appended by
``bench_all`` and uploaded by CI as an artifact.  The gate compares the
new run against the **best** throughput each scenario ever recorded —
a slow-boil regression that loses 5% per PR gets caught even though no
single step trips the snapshot gate.

History line format (one JSON object per line)::

    {"utc": "...", "jax": "...", "backend": "cpu",
     "scenarios": {"perm1024": 51234.0, ...}}

Corrupt lines are skipped with a loud warning (a truncated append must
not wedge every future bench run), and a missing file is simply an
empty history.
"""
from __future__ import annotations

import json
import sys
from typing import List, Optional


def record_from_report(report: dict) -> dict:
    """Distill a BENCH_fabric.json report dict to one history line."""
    meta = report.get("meta") or {}
    scenarios = {}
    for name, row in (report.get("scenarios") or {}).items():
        try:
            scenarios[name] = float(row["warp"]["ticks_per_s"])
        except (KeyError, TypeError, ValueError):
            continue
    return {"utc": meta.get("utc", ""), "jax": meta.get("jax", ""),
            "backend": meta.get("backend", ""), "scenarios": scenarios}


def load_history(path: str) -> List[dict]:
    """All well-formed history lines; [] when the file is missing."""
    try:
        with open(path) as f:
            lines = f.readlines()
    except FileNotFoundError:
        return []
    except OSError as e:
        print(f"trend gate: cannot read {path} ({e}) — empty history",
              file=sys.stderr)
        return []
    out = []
    for ln, line in enumerate(lines, 1):
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            print(f"trend gate: {path}:{ln}: corrupt line skipped",
                  file=sys.stderr)
            continue
        if isinstance(rec, dict) and isinstance(rec.get("scenarios"),
                                                dict):
            out.append(rec)
        else:
            print(f"trend gate: {path}:{ln}: malformed record skipped",
                  file=sys.stderr)
    return out


def append_run(path: str, record: dict) -> None:
    with open(path, "a") as f:
        f.write(json.dumps(record, sort_keys=True) + "\n")


def trend_problems(history: List[dict], record: dict,
                   tol: float = 0.20) -> List[str]:
    """Gate ``record`` against the best-ever throughput per scenario.

    A scenario regresses when its new ticks/sec is more than ``tol``
    below the maximum any history line recorded for it.  Scenarios with
    no history land silently (new benchmarks need no baseline)."""
    best: dict = {}
    for rec in history:
        for name, tps in rec["scenarios"].items():
            try:
                tps = float(tps)
            except (TypeError, ValueError):
                continue
            if tps > best.get(name, 0.0):
                best[name] = tps
    problems = []
    for name, tps in sorted((record.get("scenarios") or {}).items()):
        ref = best.get(name)
        if ref and ref > 0 and tps < (1.0 - tol) * ref:
            problems.append(
                f"trend: scenarios.{name} warp ticks/sec is "
                f"{(1 - tps / ref) * 100:.1f}% below the best run in "
                f"history ({ref:,.1f} -> {tps:,.1f}; gate is {tol:.0%})")
    return problems


def gate_and_append(path: str, report: dict,
                    tol: float = 0.20,
                    record: Optional[dict] = None) -> List[str]:
    """The bench_all hook: distill, gate vs history, then append.

    The new run is appended even when it regresses — the trajectory
    must show the bad run, and the process exit code (driven by the
    returned problems) is the gate."""
    rec = record if record is not None else record_from_report(report)
    problems = trend_problems(load_history(path), rec, tol=tol)
    try:
        append_run(path, rec)
        print(f"trend: appended run to {path} "
              f"({len(rec['scenarios'])} scenarios)")
    except OSError as e:
        print(f"trend gate: cannot append to {path} ({e})",
              file=sys.stderr)
    return problems
