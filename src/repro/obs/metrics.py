"""Minimal Prometheus-style metrics: registry + text render + parser.

One class and two functions, stdlib only:

  * :class:`MetricsRegistry` — named counters and gauges with label
    sets.  ``inc()`` accumulates (counter semantics), ``set()``
    overwrites (gauge semantics); each name carries a HELP string and a
    TYPE so the rendered exposition is self-describing.
  * :func:`render_prometheus` — the text exposition format (version
    0.0.4): ``# HELP`` / ``# TYPE`` comment pairs followed by
    ``name{label="value",...} number`` sample lines.
  * :func:`parse_prometheus` — the inverse, strict enough to be a
    round-trip gate in the test suite and in ``benchmarks/soak.py``:
    every sample line must parse, every samples' name must have been
    declared by a TYPE line.

The fabric's metric names all live under the ``strack_`` prefix; see
docs/observatory.md for the full name/label catalogue.
"""
from __future__ import annotations

import math
import re
from typing import Dict, Iterable, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
# name{labels} value   (labels optional; value is any float literal)
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r"\s+(?P<value>[^\s]+)$")
_LABEL_PAIR_RE = re.compile(
    r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')

LabelSet = Tuple[Tuple[str, str], ...]


def _labelset(labels: Dict[str, str]) -> LabelSet:
    for k in labels:
        if not _LABEL_RE.match(k):
            raise ValueError(f"bad label name {k!r}")
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _unescape(v: str) -> str:
    return (v.replace("\\n", "\n").replace('\\"', '"')
            .replace("\\\\", "\\"))


class MetricsRegistry:
    """Counters and gauges keyed by (metric name, label set).

    ``declare`` is idempotent; ``inc``/``set`` auto-declare with an
    empty HELP when the name is new, so ad-hoc use stays one-liner
    cheap while the soak driver declares everything up front with
    proper HELP strings.
    """

    def __init__(self):
        # name -> (help, type); insertion order = exposition order
        self._meta: Dict[str, Tuple[str, str]] = {}
        # name -> {labelset: value}
        self._samples: Dict[str, Dict[LabelSet, float]] = {}

    def declare(self, name: str, help: str = "",
                type: str = "gauge") -> None:
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r}")
        if type not in ("counter", "gauge"):
            raise ValueError(f"bad metric type {type!r}")
        old = self._meta.get(name)
        if old is not None and old[1] != type:
            raise ValueError(f"metric {name!r} re-declared as {type}, "
                             f"was {old[1]}")
        if old is None or (not old[0] and help):
            self._meta[name] = (help, type)
        self._samples.setdefault(name, {})

    def inc(self, name: str, delta: float = 1.0, **labels) -> None:
        """Counter-style accumulate (declares ``name`` as counter)."""
        if name not in self._meta:
            self.declare(name, type="counter")
        key = _labelset(labels)
        self._samples[name][key] = self._samples[name].get(key, 0.0) + delta

    def set(self, name: str, value: float, **labels) -> None:
        """Gauge-style overwrite (declares ``name`` as gauge)."""
        if name not in self._meta:
            self.declare(name, type="gauge")
        self._samples[name][_labelset(labels)] = float(value)

    def get(self, name: str, **labels) -> float:
        return self._samples[name][_labelset(labels)]

    def samples(self) -> Iterable[Tuple[str, LabelSet, float]]:
        for name, by_labels in self._samples.items():
            for key, value in sorted(by_labels.items()):
                yield name, key, value


def _fmt_value(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def render_prometheus(reg: MetricsRegistry) -> str:
    """Prometheus text exposition (0.0.4) of every declared metric."""
    out = []
    for name, (help, type) in reg._meta.items():
        if help:
            out.append(f"# HELP {name} {_escape(help)}")
        out.append(f"# TYPE {name} {type}")
        for key, value in sorted(reg._samples.get(name, {}).items()):
            if key:
                lbl = ",".join(f'{k}="{_escape(v)}"' for k, v in key)
                out.append(f"{name}{{{lbl}}} {_fmt_value(value)}")
            else:
                out.append(f"{name} {_fmt_value(value)}")
    return "\n".join(out) + "\n"


def parse_prometheus(text: str) -> Dict[Tuple[str, LabelSet], float]:
    """Parse a text exposition back to ``{(name, labelset): value}``.

    Strict: raises ``ValueError`` on an unparseable sample line, on a
    sample whose metric has no preceding ``# TYPE`` declaration, or on
    an unknown metric type — the round-trip gate the soak smoke and CI
    use to prove the ``.prom`` file is real Prometheus format.
    """
    declared: Dict[str, str] = {}
    out: Dict[Tuple[str, LabelSet], float] = {}
    for ln, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("# TYPE "):
            parts = line.split(None, 3)
            if len(parts) != 4 or parts[3] not in ("counter", "gauge",
                                                   "histogram", "summary",
                                                   "untyped"):
                raise ValueError(f"line {ln}: bad TYPE line {line!r}")
            declared[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {ln}: unparseable sample {line!r}")
        name = m.group("name")
        if name not in declared:
            raise ValueError(f"line {ln}: sample for undeclared metric "
                             f"{name!r}")
        labels: Dict[str, str] = {}
        raw = m.group("labels")
        if raw:
            consumed = 0
            for pm in _LABEL_PAIR_RE.finditer(raw):
                labels[pm.group(1)] = _unescape(pm.group(2))
                consumed += len(pm.group(0))
            if consumed < len(raw.replace(",", "").replace(" ", "")):
                raise ValueError(f"line {ln}: bad label block {raw!r}")
        v = m.group("value")
        try:
            value = float(v)
        except ValueError:
            raise ValueError(f"line {ln}: bad sample value {v!r}")
        out[(name, _labelset(labels))] = value
    return out
