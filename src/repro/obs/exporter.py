"""One-file stdlib Prometheus exporter: serve a ``.prom`` file over HTTP.

The soak driver writes its metrics file (``make soak`` ->
``BENCH_soak.prom``); this module serves it so a Prometheus scraper or
a browser can watch a long soak converge:

    make serve-metrics                  # BENCH_soak.prom on :9109
    PYTHONPATH=src python -m repro.obs.exporter \
        --file BENCH_soak.prom --port 9109

``GET /metrics`` (and ``/``) returns the file's current content with
the text-exposition content type, re-read on every scrape so a running
soak's periodic dumps show up live.  404 on other paths, 503 when the
file does not exist yet.  Stdlib ``http.server`` only — no
prometheus_client dependency.
"""
from __future__ import annotations

import argparse
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def make_handler(path: str):
    class MetricsHandler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 (http.server API)
            if self.path not in ("/", "/metrics"):
                self.send_error(404, "try /metrics")
                return
            try:
                with open(path, "rb") as f:
                    body = f.read()
            except OSError as e:
                self.send_error(503, f"metrics file not readable: {e}")
                return
            self.send_response(200)
            self.send_header("Content-Type", CONTENT_TYPE)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, fmt, *args):  # quiet scrape log
            pass

    return MetricsHandler


def make_server(path: str, port: int = 9109,
                host: str = "127.0.0.1") -> ThreadingHTTPServer:
    """Build (but do not run) the server; port 0 picks an ephemeral
    port — ``server.server_address[1]`` has the real one (tests use
    this)."""
    return ThreadingHTTPServer((host, port), make_handler(path))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--file", default="BENCH_soak.prom",
                    help="metrics file to serve (re-read per scrape)")
    ap.add_argument("--port", type=int, default=9109)
    ap.add_argument("--host", default="127.0.0.1")
    args = ap.parse_args()
    srv = make_server(args.file, args.port, args.host)
    host, port = srv.server_address[:2]
    print(f"serving {args.file} on http://{host}:{port}/metrics "
          f"(ctrl-c to stop)")
    try:
        srv.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        srv.server_close()


if __name__ == "__main__":
    main()
