"""Sharded, atomic, elastic checkpointing.

Layout:  <dir>/step_<N>/  manifest.json + one .npy per leaf (path-encoded).
Writes go to ``step_<N>.tmp`` then a single atomic rename — a crashed writer
can never corrupt the latest complete checkpoint.  Restore takes target
shardings, so a checkpoint saved on one mesh restores onto another
(elastic reshard: e.g. 256-chip pod -> 512-chip multi-pod).

On a real multi-host deployment each host would write only its addressable
shards (same manifest format, per-shard files); this container is
single-process so leaves are materialised whole.  The format and the
atomic-rename protocol are identical.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _leaf_files(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "__".join(str(getattr(k, "key", getattr(k, "idx", k)))
                         for k in path)
        out.append((name, leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, tree, extra: dict | None = None) -> str:
    """Atomic checkpoint write. Returns the final directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    files, _ = _leaf_files(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for name, leaf in files:
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"][name] = {"shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)   # atomic publish
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; optionally reshard.

    ``shardings`` (a pytree of NamedSharding matching like_tree) places each
    leaf on the current mesh — this is the elastic-scaling path: the saved
    mesh shape is irrelevant.
    Returns (tree, extra_dict).
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    files, treedef = _leaf_files(like_tree)
    leaves = []
    shard_list = (jax.tree.leaves(
        shardings, is_leaf=lambda s: hasattr(s, "spec"))
        if shardings is not None else [None] * len(files))
    for (name, like), shard in zip(files, shard_list):
        arr = np.load(os.path.join(d, name + ".npy"))
        assert list(arr.shape) == list(like.shape), (name, arr.shape,
                                                     like.shape)
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]
