"""Serving steps: batched prefill and single-token decode with KV/SSM caches.

``serve_step`` (decode) is what the ``decode_*`` / ``long_*`` shapes lower:
one new token against a seq_len-deep cache.  The KV cache is
sequence-sharded over the ``model`` axis (parallel/sharding.cache_specs) —
the long-context serving layout."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import lm
from ..models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig):
    """prefill(params, batch) -> last-position logits (B, vocab)."""

    def prefill(params, batch):
        tokens = batch["tokens"]
        B, T = tokens.shape
        x = lm.embed_tokens(params, tokens, cfg)
        enc_out = None
        if cfg.kind == "vlm":
            x = jnp.concatenate([batch["vis_embed"].astype(x.dtype), x],
                                axis=1)
        if cfg.kind == "encdec":
            enc_out = lm.encode(params, batch["frames"].astype(x.dtype), cfg)
        Tt = x.shape[1]
        pos = jnp.broadcast_to(jnp.arange(Tt, dtype=jnp.int32)[None],
                               (B, Tt))
        hidden, _ = lm.forward_hidden(params, x, pos, cfg, enc_out=enc_out)
        w = lm.lm_head_weight(params, cfg)
        logits = hidden[:, -1] @ w.astype(hidden.dtype)
        return logits.astype(jnp.float32)

    return prefill


def make_decode_step(cfg: ModelConfig):
    """decode(params, cache, tokens(B,1), pos) -> (logits, new_cache)."""

    def decode(params, cache, tokens, pos):
        return lm.decode_step(params, cache, tokens, pos, cfg)

    return decode


def greedy_generate(params, cfg: ModelConfig, prompt, max_new: int,
                    cache_len: int):
    """Simple batched greedy generation loop (examples / tests)."""
    B, T = prompt.shape
    cache = lm.init_cache(cfg, B, cache_len)
    step = jax.jit(make_decode_step(cfg))
    tok = prompt[:, :1]
    out = []
    pos = 0
    # teacher-forced prompt consumption, then greedy continuation
    for t in range(T + max_new - 1):
        logits, cache = step(params, cache, tok, jnp.asarray(pos, jnp.int32))
        pos += 1
        if t + 1 < T:
            tok = prompt[:, t + 1:t + 2]
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out.append(tok)
    return jnp.concatenate(out, axis=1) if out else prompt[:, :0]
