"""Fault tolerance + elasticity + straggler mitigation for 1000+-node runs.

Three layers of defence (DESIGN.md §7):

1. **Transport (STrack itself)** — link-level stragglers/failures are routed
   around by adaptive spray within an RTT; no training-loop involvement
   (benchmarks/oversub_linkdown.py quantifies this).

2. **Step-level** — `TrainSupervisor` below: checkpoint every N steps
   (atomic, sharded), detect failures (in production: missed heartbeats /
   jax.distributed errors; here: injected exceptions), restart from the
   last complete checkpoint with bit-exact data-pipeline state.

3. **Cluster-level elasticity** — checkpoints are mesh-independent
   (runtime/checkpoint.restore takes target shardings), so a restart may
   resize e.g. 512 -> 256 chips. `scale_batch_rule` keeps the global batch
   constant by adjusting grad-accumulation steps.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax

from . import checkpoint as ckpt


@dataclasses.dataclass
class SupervisorConfig:
    ckpt_dir: str = "checkpoints"
    ckpt_every: int = 50
    max_restarts: int = 10
    step_deadline_s: Optional[float] = None   # straggler watchdog (prod)


def scale_batch_rule(global_batch: int, micro_batches: int,
                     old_chips: int, new_chips: int) -> int:
    """Keep the global batch constant across a resize by scaling
    grad-accumulation (micro-batch count)."""
    scaled = micro_batches * old_chips / new_chips
    return max(1, int(math.ceil(scaled)))


class TrainSupervisor:
    """Checkpoint/restart loop around a step function.

    The driver calls ``run``; any exception from ``step_fn`` (a real node
    failure surfaces as one under jax.distributed) triggers a restore of
    the last complete checkpoint — including RNG/data state — and the run
    continues bit-exactly (tests/test_elastic.py)."""

    def __init__(self, cfg: SupervisorConfig, state, dataset,
                 step_fn: Callable, shardings=None):
        self.cfg = cfg
        self.state = state          # (params, opt)
        self.dataset = dataset
        self.step_fn = step_fn
        self.shardings = shardings
        self.restarts = 0
        self.metrics_log: list = []

    def _save(self, step: int):
        ckpt.save(self.cfg.ckpt_dir, step,
                  {"params": self.state[0], "opt": self.state[1]},
                  extra={"data": self.dataset.state_dict(), "step": step})

    def _restore(self) -> int:
        last = ckpt.latest_step(self.cfg.ckpt_dir)
        if last is None:
            return 0
        like = {"params": self.state[0], "opt": self.state[1]}
        tree, extra = ckpt.restore(self.cfg.ckpt_dir, last, like,
                                   shardings=self.shardings)
        self.state = (tree["params"], tree["opt"])
        self.dataset.load_state_dict(extra["data"])
        return int(extra["step"])

    def run(self, n_steps: int, fail_at: Optional[set] = None):
        """fail_at: steps at which to inject a simulated node failure."""
        step = 0
        self._save(0)
        while step < n_steps:
            try:
                if fail_at and step in fail_at:
                    fail_at = fail_at - {step}
                    raise RuntimeError(f"injected node failure @ {step}")
                batch = self.dataset.batch_at(step)
                params, opt, metrics = self.step_fn(self.state[0],
                                                    self.state[1], batch)
                self.state = (params, opt)
                self.dataset.step = step + 1
                self.metrics_log.append((step, float(metrics["loss"])))
                step += 1
                if step % self.cfg.ckpt_every == 0:
                    self._save(step)
            except RuntimeError:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                step = self._restore()
        self._save(n_steps)
        return self.state
