"""Train-step factory: loss + grads + AdamW, with microbatch gradient
accumulation (compute/comm overlap lever) — everything a single pjit'd XLA
program on the production mesh."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..models import lm
from ..models.config import ModelConfig
from .optimizer import OptConfig, OptState, apply_updates, init_opt


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig,
                    micro_batches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With micro_batches > 1 the batch is split along dim 0 and gradients are
    accumulated in a lax.scan — the optimizer (and its DP all-reduce) runs
    once per step, letting XLA overlap grad compute with grad reduction.
    """

    def loss_fn(params, batch):
        return lm.lm_loss(params, batch, cfg)

    def train_step(params, opt_state: OptState, batch):
        if micro_batches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                assert b % micro_batches == 0, (b, micro_batches)
                return x.reshape((micro_batches, b // micro_batches)
                                 + x.shape[1:])
            mb = jax.tree.map(reshape, batch)

            def acc(carry, mbatch):
                tot_loss, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(params, mbatch)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (tot_loss + l, g_acc), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), g0), mb)
            loss = loss / micro_batches
            grads = jax.tree.map(lambda g: g / micro_batches, grads)
        params, opt_state, metrics = apply_updates(params, grads, opt_state,
                                                   opt_cfg)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, opt_cfg: OptConfig):
    params = lm.init_params(key, cfg)
    return params, init_opt(params, opt_cfg)
