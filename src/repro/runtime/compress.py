"""Hierarchical int8 inter-pod gradient reduction (wire-level compression).

EXPERIMENTS.md §Perf cell 3 lesson 4: quantise-dequantise around an
all-reduce is a no-op to the fabric — XLA still moves f32.  This module
restructures the reduction itself with shard_map so the *inter-pod hop*
(the STrack-relevant DCN traffic) carries int8:

    1. intra-pod psum in f32 (ICI, cheap),
    2. per-tensor symmetric int8 quantisation,
    3. inter-pod exchange of the int8 payload (collective_permute — 4x
       fewer wire bytes, visible in the compiled HLO),
    4. local dequantise + add, with the quantisation error fed back by the
       caller (runtime/optimizer.compress_grads).

For >2 pods the exchange generalises to a ring of int8 permutes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map


def _quantize(x):
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def hierarchical_int8_psum(x, mesh, *, pod_axis: str = "pod",
                           intra_axes=("data",)):
    """All-reduce ``x`` over (pod_axis + intra_axes) with int8 on the pod hop.

    x must be replicated over `model` (or further shard_map'ed by caller).
    Returns the full sum, same dtype as x.
    """
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))[pod_axis]
    assert n_pods == 2, "ring generalisation for >2 pods: TODO"

    def body(xs):
        # (1) intra-pod reduction in full precision
        local = jax.lax.psum(xs, intra_axes)
        # (2) quantise the pod-local sum
        q, scale = _quantize(local.astype(jnp.float32))
        # (3) exchange int8 payload + scale with the peer pod
        other_q = jax.lax.ppermute(q, pod_axis, [(0, 1), (1, 0)])
        other_s = jax.lax.ppermute(scale, pod_axis, [(0, 1), (1, 0)])
        # (4) dequantise and combine
        total = local.astype(jnp.float32) \
            + other_q.astype(jnp.float32) * other_s
        return total.astype(xs.dtype)

    axes = (pod_axis,) + tuple(intra_axes)
    f = shard_map(
        body, mesh=mesh,
        in_specs=P((*axes,)),     # all reduce axes stacked on dim 0
        out_specs=P((*axes,)),
        check_vma=False,
    )
    # x is logically replicated over the reduce axes: feed each device its
    # shard view by treating the leading dim... callers pass the already
    # device-local value; here we emulate with a psum-style contract:
    return f(x)


def two_stage_allreduce_bytes_demo(mesh, shape=(1024, 1024)):
    """Lower both a plain f32 psum and the hierarchical int8 version and
    return their per-device collective bytes (for tests/benchmarks)."""
    from ..launch.roofline import parse_collective_bytes
    x = jax.ShapeDtypeStruct(shape, jnp.float32)
    axes = tuple(a for a in mesh.axis_names if a != "model")

    def plain(v):
        def body(vs):
            return jax.lax.psum(vs, axes)
        return shard_map(body, mesh=mesh, in_specs=P((*axes,)),
                         out_specs=P((*axes,)), check_vma=False)(v)

    def hier(v):
        return hierarchical_int8_psum(v, mesh, pod_axis="pod",
                                      intra_axes=tuple(
                                          a for a in axes if a != "pod"))

    out = {}
    for name, fn in (("plain_f32", plain), ("hier_int8", hier)):
        c = jax.jit(fn).lower(x).compile()
        coll = parse_collective_bytes(c.as_text(), mesh.devices.size)
        out[name] = coll
    return out
