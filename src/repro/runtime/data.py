"""Deterministic synthetic data pipeline.

Tokens are a pure function of (seed, step, position) via threefry — so the
pipeline is (a) infinitely shardable (each DP shard slices its rows), (b)
checkpointable with a single integer (`step`), and (c) bit-reproducible on
restart / reshard — the property the fault-tolerance tests rely on.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0


class SyntheticDataset:
    """Stateless-per-step synthetic LM batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.step = 0

    def batch_at(self, step: int, extras: dict | None = None) -> dict:
        c = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
        toks = jax.random.randint(key, (c.global_batch, c.seq + 1), 0,
                                  c.vocab, dtype=jnp.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if extras:
            for name, shape in extras.items():
                k = jax.random.fold_in(key, hash(name) % (2 ** 31))
                batch[name] = jax.random.normal(k, shape, jnp.float32)
        return batch

    def __next__(self):
        b = self.batch_at(self.step)
        self.step += 1
        return b

    # -- checkpointing --------------------------------------------------- #
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, d: dict) -> None:
        assert d["seed"] == self.cfg.seed, "seed mismatch on restore"
        self.step = int(d["step"])
