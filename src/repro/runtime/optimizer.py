"""AdamW from scratch (pytree-native), with global-norm clipping, cosine
schedule and optional int8 gradient compression with error feedback.

The compression path quantises gradients to int8 *before* the data-parallel
all-reduce — on the production mesh this shrinks the inter-pod (DCN /
Ethernet, i.e. STrack-relevant) collective bytes 4x; the residual is carried
to the next step (error feedback) so convergence is preserved.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    grad_compress: bool = False    # int8 + error feedback


class OptState(NamedTuple):
    mu: object
    nu: object
    count: jax.Array
    err: object   # error-feedback residual (zeros when compression off)


def init_opt(params, cfg: OptConfig) -> OptState:
    z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    err = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params) \
        if cfg.grad_compress else jax.tree.map(lambda p: jnp.zeros((),
                                                                   jnp.float32),
                                               params)
    return OptState(mu=z, nu=jax.tree.map(jnp.copy, z),
                    count=jnp.zeros((), jnp.int32), err=err)


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 \
        * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def quantize_int8(g):
    """Symmetric per-tensor int8 quantisation. Returns (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads, err):
    """int8 error-feedback compression (applied before the DP all-reduce)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g)
        deq = q.astype(jnp.float32) * scale
        return deq, g - deq
    flat = jax.tree.map(one, grads, err)
    deq = jax.tree.map(lambda t: t[0], flat,
                       is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
    return deq, new_err


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(params, grads, state: OptState, cfg: OptConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if cfg.grad_compress:
        grads, new_err = compress_grads(grads, state.err)
    else:
        new_err = state.err
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-12))
    count = state.count + 1
    lr = schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay \
            * p.astype(jnp.float32)
        return (p - lr * step).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gn, "lr": lr}
    return new_params, OptState(new_mu, new_nu, count, new_err), metrics
