"""Unified model configuration covering all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    kind: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qk_norm: bool = False                   # qwen3-style per-head RMSNorm
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    experts_per_tok: int = 2
    capacity_factor: float = 1.25
    moe_group: int = 512
    # sliding-window attention (None = full causal); mixtral: 4096
    window: Optional[int] = None
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv: int = 4
    ssm_expand: int = 2
    # hybrid (zamba2): one shared attention block applied every k ssm layers
    hybrid_attn_every: int = 0
    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500                     # stub frame-embedding count
    # vlm stub
    n_vis_tokens: int = 0
    # numerics / implementation knobs
    dtype: str = "bfloat16"
    attn_impl: str = "chunked"              # naive | chunked | pallas
    attn_chunk: int = 512
    remat: str = "full"                     # none | dots | full
    scan_layers: bool = True
    # parallelism hints
    shard_experts: bool = False             # EP over a dedicated mesh axis
    # activation data-parallel axes: when set (by the launcher, from the
    # mesh), block inputs/outputs get with_sharding_constraint on batch —
    # without this GSPMD can drop batch sharding after the vocab-sharded
    # embedding gather and run the whole net batch-replicated.
    dp_axes: tuple = ()
    tp_axis: str = "model"
    tp_size: int = 0   # model-axis size (set by the launcher with dp_axes)
    gather_weights: bool = True  # False: keep weights 2D-sharded (decode)
    norm_f32: bool = True        # False: RMSNorm in bf16 (keeps TP AR bf16)
    attn_f32: bool = True        # False: online-softmax state in bf16
    # True: checkpoint each kv-chunk step of the online-softmax scan so its
    # backward RECOMPUTES the probability block instead of saving all
    # (T x S) f32 probabilities — the flash-attention backward structure.
    attn_remat_chunk: bool = False
    # Megatron-style sequence parallelism: activations between blocks are
    # sharded over (tp_axis) on the SEQUENCE dim, turning the TP all-reduce
    # into reduce-scatter + all-gather (half the bytes) and sharding norms.
    seq_shard: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.kind == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run the long_500k shape? (SSM/hybrid/SWA)"""
        return self.kind in ("ssm", "hybrid") or self.window is not None

    def param_count(self) -> float:
        """Approximate parameter count (for 6ND model-FLOPs accounting)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
            + (self.n_heads * hd) * d
        mlp = 3 * d * ff
        if self.kind == "moe":
            mlp = self.n_experts * 3 * d * ff + d * self.n_experts  # + router
        ssm = 0
        if self.kind in ("ssm", "hybrid"):
            d_in = self.ssm_expand * d
            n, h = self.ssm_state, self.ssm_heads
            # in_proj (z,x,B,C,dt) + out_proj + conv + A,D
            ssm = d * (2 * d_in + 2 * n * 1 + h) + d_in * d + \
                self.ssm_conv * (d_in + 2 * n) + 2 * h
        per_layer = mlp + (attn if self.kind not in ("ssm",) else 0)
        if self.kind == "ssm":
            per_layer = ssm
        if self.kind == "hybrid":
            n_attn = self.n_layers // max(self.hybrid_attn_every, 1)
            total = self.n_layers * (ssm + d * 2) + 1 * (attn + 3 * d * ff)
            # shared attention block counted once (it is shared)
            return total + V * d * (1 if self.tie_embeddings else 2)
        n_lay = self.n_layers + self.n_enc_layers
        total = n_lay * (per_layer + 2 * d)
        if self.n_enc_layers:  # cross attention in decoder
            total += self.n_layers * attn
        total += V * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count(self) -> float:
        """Active (per-token) params — differs for MoE (6*N_active*D)."""
        if self.kind != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_mlp = self.experts_per_tok * 3 * d * ff
        full = self.param_count()
        return full - self.n_layers * (self.n_experts - self.experts_per_tok) \
            * 3 * d * ff
