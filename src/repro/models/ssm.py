"""Mamba2 (SSD — state-space duality) block, pure JAX.

Chunked SSD algorithm (Dao & Gu 2024): within a chunk the recurrence is
computed as masked matmuls (MXU-friendly); across chunks a scan carries the
(H, N, P) state.  Decode is the O(1) recurrent update — the reason the
``long_500k`` shape is feasible for SSM/hybrid archs.

Shapes: x (B,T,H,P) heads x head_dim; B̂,Ĉ (B,T,N) (single group);
A (H,) negative reals; dt (B,T,H) positive.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import rms_norm, init_linear


def init_mamba2(key, cfg: ModelConfig, d_model=None):
    d = d_model or cfg.d_model
    d_in = cfg.ssm_expand * d
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    assert H * P == d_in, (H, P, d_in)
    ks = jax.random.split(key, 8)
    return {
        # separate in-projections (z, x, B, C, dt) so each output dim is
        # independently TP-shardable (a fused concat has a ragged width)
        "w_z": init_linear(ks[0], d, d_in),
        "w_x": init_linear(ks[1], d, d_in),
        "w_B": init_linear(ks[2], d, N),
        "w_C": init_linear(ks[3], d, N),
        "w_dt": init_linear(ks[4], d, H),
        "w_out": init_linear(ks[5], d_in, d),
        "conv_x": jax.random.normal(ks[6], (cfg.ssm_conv, d_in),
                                    jnp.float32) * 0.2,
        "conv_B": jax.random.normal(ks[7], (cfg.ssm_conv, N),
                                    jnp.float32) * 0.2,
        "conv_C": jax.random.normal(ks[7], (cfg.ssm_conv, N),
                                    jnp.float32) * 0.2,
        "conv_bx": jnp.zeros((d_in,), jnp.float32),
        "conv_bB": jnp.zeros((N,), jnp.float32),
        "conv_bC": jnp.zeros((N,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H).astype(jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), math.log(math.e - 1), jnp.float32),
        "norm_w": jnp.ones((d_in,), jnp.float32),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv1d. x: (B,T,C), w: (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _split_in(p, cfg, u):
    from .layers import fsdp_gather
    dt_c = u.dtype
    z = u @ fsdp_gather(p["w_z"], cfg, -1).astype(dt_c)
    x = u @ fsdp_gather(p["w_x"], cfg, -1).astype(dt_c)
    B_ = u @ fsdp_gather(p["w_B"], cfg, -1).astype(dt_c)
    C_ = u @ fsdp_gather(p["w_C"], cfg, -1).astype(dt_c)
    dt = u @ fsdp_gather(p["w_dt"], cfg, -1).astype(dt_c)
    return z, x, B_, C_, dt


def ssd_chunked(x, dt, A, B_, C_, chunk):
    """Chunked SSD scan. Returns (y, final_state).

    x (B,T,H,P), dt (B,T,H), A (H,), B_/C_ (B,T,N)."""
    Bb, T, H, P = x.shape
    N = B_.shape[-1]
    L = min(chunk, T)
    nc = T // L
    assert nc * L == T, (T, L)
    f32 = jnp.float32
    xc = x.reshape(Bb, nc, L, H, P).transpose(1, 0, 2, 3, 4).astype(f32)
    dtc = dt.reshape(Bb, nc, L, H).transpose(1, 0, 2, 3).astype(f32)
    Bc = B_.reshape(Bb, nc, L, N).transpose(1, 0, 2, 3).astype(f32)
    Cc = C_.reshape(Bb, nc, L, N).transpose(1, 0, 2, 3).astype(f32)

    tri = jnp.tril(jnp.ones((L, L), bool))

    def step(state, inp):
        xk, dtk, Bk, Ck = inp           # (B,L,H,P) (B,L,H) (B,L,N) (B,L,N)
        lam = dtk * A                   # (B,L,H) log-decay per step (A<0)
        cs = jnp.cumsum(lam, axis=1)    # (B,L,H)
        dtx = dtk[..., None] * xk       # (B,L,H,P)
        # intra-chunk: masked attention-like matmuls.  The mask must be
        # applied INSIDE the exp: upper-triangle (future) entries have
        # positive log-decay that overflows, and inf*0 NaNs the backward.
        CB = jnp.einsum("bln,bmn->blm", Ck, Bk)              # (B,L,L)
        diff = cs[:, :, None, :] - cs[:, None, :, :]         # (B,L,L,H)
        diff = jnp.where(tri[None, :, :, None], diff, -jnp.inf)
        decay = jnp.exp(diff)
        y_intra = jnp.einsum("blm,blmh,bmhp->blhp", CB, decay, dtx)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("bln,bhnp->blhp", Ck, state) \
            * jnp.exp(cs)[..., None]
        # state update
        cs_last = cs[:, -1, :]                                # (B,H)
        w = jnp.exp(cs_last[:, None, :] - cs)                 # (B,L,H)
        state_new = jnp.exp(cs_last)[:, :, None, None] * state \
            + jnp.einsum("bln,blh,blhp->bhnp", Bk, w, dtx)
        return state_new, y_intra + y_inter

    s0 = jnp.zeros((Bb, H, N, P), f32)
    final, yc = jax.lax.scan(step, s0, (xc, dtc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(Bb, T, H, P)
    return y, final


def apply_mamba2(p, u, cfg: ModelConfig, cache=None):
    """Full Mamba2 block. u: (B,T,d). cache: dict(state, conv, pos) or None.

    Returns (out (B,T,d), new_cache)."""
    dt_c = u.dtype
    B, T, d = u.shape
    d_in = cfg.ssm_expand * d
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    z, x, B_, C_, dt = _split_in(p, cfg, u.astype(jnp.bfloat16))

    new_cache = None
    if cache is None:
        x = jax.nn.silu(_causal_conv(x.astype(jnp.float32),
                                     p["conv_x"], p["conv_bx"]))
        B_ = jax.nn.silu(_causal_conv(B_.astype(jnp.float32),
                                      p["conv_B"], p["conv_bB"]))
        C_ = jax.nn.silu(_causal_conv(C_.astype(jnp.float32),
                                      p["conv_C"], p["conv_bC"]))
    else:
        # decode: roll the per-stream conv windows
        def roll(val, win, w, b):
            win = jnp.concatenate([win, val.astype(jnp.float32)], axis=1)
            out = jnp.einsum("bkc,kc->bc", win, w) + b
            return jax.nn.silu(out)[:, None, :], win[:, 1:, :]
        x, new_cx = roll(x, cache["conv_x"], p["conv_x"], p["conv_bx"])
        B_, new_cB = roll(B_, cache["conv_B"], p["conv_B"], p["conv_bB"])
        C_, new_cC = roll(C_, cache["conv_C"], p["conv_C"], p["conv_bC"])

    x = x.reshape(B, T, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    if cache is None:
        y, final = ssd_chunked(x, dt, A, B_, C_, cfg.ssm_chunk)
        new_cache = None
    else:
        # recurrent step: S = exp(dt*A) S + dt * B ⊗ x ; y = C·S
        state = cache["state"]                     # (B,H,N,P)
        dt1 = dt[:, 0]                             # (B,H)
        a = jnp.exp(dt1 * A)                       # (B,H)
        dtx = dt1[..., None] * x[:, 0].astype(jnp.float32)   # (B,H,P)
        state = a[:, :, None, None] * state \
            + jnp.einsum("bn,bhp->bhnp", B_[:, 0].astype(jnp.float32), dtx)
        y = jnp.einsum("bn,bhnp->bhp", C_[:, 0].astype(jnp.float32), state)
        y = y[:, None]                             # (B,1,H,P)
        new_cache = {"state": state, "conv_x": new_cx, "conv_B": new_cB,
                     "conv_C": new_cC, "pos": cache["pos"] + T}
        final = state

    y = y + p["D"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(B, T, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y, p["norm_w"], cfg.norm_eps)
    from .layers import fsdp_gather
    out = (y.astype(jnp.bfloat16)
           @ fsdp_gather(p["w_out"], cfg, 0).astype(jnp.bfloat16))
    return out.astype(dt_c), new_cache


def init_ssm_cache(cfg: ModelConfig, batch: int, d_model=None,
                   dtype=jnp.float32):
    d = d_model or cfg.d_model
    d_in = cfg.ssm_expand * d
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    K = cfg.ssm_conv
    return {
        "state": jnp.zeros((batch, H, N, P), jnp.float32),
        "conv_x": jnp.zeros((batch, K - 1, d_in), jnp.float32),
        "conv_B": jnp.zeros((batch, K - 1, N), jnp.float32),
        "conv_C": jnp.zeros((batch, K - 1, N), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }
