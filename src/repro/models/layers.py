"""Transformer building blocks, pure JAX (no framework).

Parameters are plain dict pytrees; every ``init_*`` returns a pytree and the
matching ``apply_*`` consumes it.  Master params are fp32; compute casts to
``cfg.dtype`` (bf16) — the standard mixed-precision recipe.

Attention implementations:
  * ``naive``   — materialise (T, S) scores (reference; small shapes).
  * ``chunked`` — online-softmax scan over KV chunks (flash-attention
    algorithm in pure JAX; O(T·chunk) memory). TPU-idiomatic: XLA maps the
    inner matmuls onto the MXU and never materialises the score matrix.
  * ``pallas``  — repro.kernels.flash_attention (explicit VMEM tiling).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def fsdp_gather(w, cfg: ModelConfig, tp_dim: int = -1):
    """Unshard a weight's FSDP (data) axis at its use site, keeping only
    the TP axis on ``tp_dim`` — manual FSDP: forward all-gathers the weight
    (cheap: O(params)), backward reduce-scatters its gradient.  Without
    this GSPMD keeps weights contraction-sharded and all-reduces O(activations)
    partial sums instead.  No-op outside the launcher (dp_axes unset)."""
    if not cfg.dp_axes or not cfg.gather_weights \
            or getattr(w, "ndim", 0) < 2:
        return w
    from jax.sharding import PartitionSpec as P
    spec = [None] * w.ndim
    d = tp_dim % w.ndim
    if cfg.tp_size and w.shape[d] % cfg.tp_size == 0:
        spec[d] = cfg.tp_axis
    return jax.lax.with_sharding_constraint(w, P(*spec))


# --------------------------------------------------------------------------- #
# primitives
# --------------------------------------------------------------------------- #

def init_linear(key, d_in, d_out, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale)


def rms_norm(x, w, eps, f32=True):
    dt = x.dtype
    if f32:
        x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(x.dtype)).astype(dt)


def rope_angles(positions, hd, theta):
    """positions: int32[...]. Returns (cos, sin) of shape (..., hd//2)."""
    freqs = jnp.exp(
        -jnp.arange(0, hd, 2, dtype=jnp.float32) / hd * math.log(theta))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (..., T, n, hd); cos/sin: (..., T, hd//2) broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate(
        [x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------- #
# attention
# --------------------------------------------------------------------------- #

def init_attention(key, cfg: ModelConfig, d_model=None):
    d = d_model or cfg.d_model
    hd = cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": init_linear(k1, d, cfg.n_heads * hd),
        "wk": init_linear(k2, d, cfg.n_kv_heads * hd),
        "wv": init_linear(k3, d, cfg.n_kv_heads * hd),
        "wo": init_linear(k4, cfg.n_heads * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def _mask_bias(q_pos, k_pos, causal, window):
    """additive mask bias (..., T, S) from query/key positions."""
    ok = jnp.ones((), bool)
    m = (k_pos[..., None, :] <= q_pos[..., :, None]) if causal else None
    if window is not None:
        w = k_pos[..., None, :] > (q_pos[..., :, None] - window)
        m = w if m is None else (m & w)
    if m is None:
        return None
    return jnp.where(m, 0.0, -jnp.inf).astype(jnp.float32)


def _sdpa_naive(q, k, v, q_pos, k_pos, causal, window):
    """q: (B,T,H,hd)  k,v: (B,S,K,hd)  GQA via head grouping."""
    B, T, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, T, K, G, hd)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    bias = _mask_bias(q_pos, k_pos, causal, window)
    if bias is not None:
        scores = scores + bias[:, None, None, :, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskh->btkgh", probs, v)
    return out.reshape(B, T, H, hd)


def _sdpa_chunked(q, k, v, q_pos, k_pos, causal, window, chunk,
                  f32=True, remat_chunk=False):
    """Online-softmax over KV chunks (flash algorithm, pure JAX)."""
    acc_dt = jnp.float32 if f32 else jnp.bfloat16
    B, T, H, hd = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    nc = max(1, math.ceil(S / chunk))
    pad = nc * chunk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-(10 ** 9))
    kc = k.reshape(B, nc, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nc, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(B, nc, chunk).transpose(1, 0, 2)
    qg = q.reshape(B, T, K, G, hd)
    scale = 1.0 / math.sqrt(hd)

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp
        s = jnp.einsum("btkgh,bskh->bkgts", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        bias = _mask_bias(q_pos, pb, causal, window)
        if bias is not None:
            s = s + bias[:, None, None, :, :]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isinf(s), 0.0, p)
        corr = jnp.exp(jnp.where(jnp.isinf(m), -jnp.inf, m) - m_safe)
        corr = jnp.where(jnp.isnan(corr), 0.0, corr).astype(acc_dt)
        l_new = l * corr + jnp.sum(p, axis=-1).astype(acc_dt)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgts,bskh->bkgth", p.astype(vb.dtype), vb).astype(acc_dt)
        return (m_new, l_new, acc_new), None

    if remat_chunk:
        step = jax.checkpoint(
            step, policy=jax.checkpoint_policies.nothing_saveable)
    m0 = jnp.full((B, K, G, T), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, K, G, T), acc_dt)
    a0 = jnp.zeros((B, K, G, T, hd), acc_dt)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, T, H, hd)
    return out.astype(q.dtype)


def apply_attention(p, x, cfg: ModelConfig, *, positions, kv=None,
                    cache=None, causal=True, window=None,
                    cross_kv=None):
    """General attention.

    x: (B, T, d).  positions: (B, T) int32 absolute positions.
    cache: optional dict(k, v, pos) for decode — updated in place and
    returned.  cross_kv: (k, v) from an encoder (cross-attention).
    Returns (out, new_cache).
    """
    dt = dtype_of(cfg)
    B, T, d = x.shape
    hd = cfg.hd
    xq = x.astype(dt)
    wq = fsdp_gather(p["wq"], cfg, -1)
    q = (xq @ wq.astype(dt)).reshape(B, T, cfg.n_heads, hd)
    if cross_kv is None:
        wk = fsdp_gather(p["wk"], cfg, -1)
        wv = fsdp_gather(p["wv"], cfg, -1)
        k = (xq @ wk.astype(dt)).reshape(B, T, cfg.n_kv_heads, hd)
        v = (xq @ wv.astype(dt)).reshape(B, T, cfg.n_kv_heads, hd)
    else:
        k, v = cross_kv
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        if cross_kv is None:
            k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cross_kv is None:
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is not None:
        # decode: write this step's k/v at cache position (ring for SWA)
        S = cache["k"].shape[1]
        pos = cache["pos"]          # scalar int32: absolute position
        slot = pos % S if window is not None else pos
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        if window is not None:
            base = pos - (pos % S)
            k_pos = jnp.arange(S, dtype=jnp.int32)[None, :] + base
            k_pos = jnp.where(k_pos > pos, k_pos - S, k_pos)
            k_pos = jnp.broadcast_to(k_pos, (B, S))
        else:
            k_pos = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
            k_pos = jnp.where(k_pos <= pos, k_pos, 10 ** 9)  # mask unwritten
        new_cache = {"k": ck, "v": cv, "pos": pos + T}
        k, v = ck, cv
        q_pos = positions
    else:
        if cross_kv is None:
            k_pos = positions
        else:
            k_pos = jnp.broadcast_to(
                jnp.arange(k.shape[1], dtype=jnp.int32)[None, :],
                (B, k.shape[1]))
        q_pos = positions

    impl = cfg.attn_impl
    if impl == "pallas":
        from ..kernels import ops as kops
        out = kops.flash_attention(q, k, v, q_pos, k_pos, causal=causal,
                                   window=window)
    elif impl == "chunked" and k.shape[1] > cfg.attn_chunk and T > 1:
        # T == 1 (decode) always takes the naive path: the scores row is
        # tiny and reduces over the (sequence-sharded) cache with small
        # psums, whereas the chunked scan's reshape would force the cache
        # to be all-gathered.
        out = _sdpa_chunked(q, k, v, q_pos, k_pos, causal, window,
                            cfg.attn_chunk, f32=cfg.attn_f32,
                            remat_chunk=cfg.attn_remat_chunk)
    else:
        out = _sdpa_naive(q, k, v, q_pos, k_pos, causal, window)
    out = out.reshape(B, T, cfg.n_heads * hd)
    return out @ fsdp_gather(p["wo"], cfg, 0).astype(dt), new_cache


# --------------------------------------------------------------------------- #
# MLP (SwiGLU)
# --------------------------------------------------------------------------- #

def init_mlp(key, d, ff):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wg": init_linear(k1, d, ff), "wu": init_linear(k2, d, ff),
            "wd": init_linear(k3, ff, d)}


def apply_mlp(p, x, cfg: ModelConfig):
    dt = dtype_of(cfg)
    x = x.astype(dt)
    g = jax.nn.silu(x @ fsdp_gather(p["wg"], cfg, -1).astype(dt))
    u = x @ fsdp_gather(p["wu"], cfg, -1).astype(dt)
    return (g * u) @ fsdp_gather(p["wd"], cfg, 0).astype(dt)


# --------------------------------------------------------------------------- #
# Mixture of Experts (top-k, group-wise capacity dispatch)
# --------------------------------------------------------------------------- #

def init_moe(key, cfg: ModelConfig):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    return {
        "router": init_linear(k0, d, E),
        "wg": jax.random.normal(k1, (E, d, ff), jnp.float32) * scale,
        "wu": jax.random.normal(k2, (E, d, ff), jnp.float32) * scale,
        "wd": jax.random.normal(k3, (E, ff, d), jnp.float32) / math.sqrt(ff),
    }


def apply_moe(p, x, cfg: ModelConfig, group: int = None):
    """Top-k routing with per-group expert capacity (dropping overflow).

    Dense one-hot dispatch/combine einsums — the Mesh-TensorFlow style that
    lowers to all-to-alls when experts are sharded on a mesh axis.
    Returns (y, aux_loss).
    """
    dt = dtype_of(cfg)
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_tok
    g = min(group or cfg.moe_group, T)
    G = T // g
    xg = x.reshape(B * G, g, d).astype(dt)
    S = xg.shape[0]

    logits = (xg @ p["router"].astype(dt)).astype(jnp.float32)  # (S,g,E)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)                        # (S,g,k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    C = max(1, int(cfg.capacity_factor * g * k / E))
    onehot = jax.nn.one_hot(topi, E, dtype=jnp.float32)         # (S,g,k,E)
    # position of each (token, slot) within its expert queue
    pos = jnp.cumsum(onehot.reshape(S, g * k, E), axis=1).reshape(
        S, g, k, E) * onehot - 1.0
    keep = (pos < C) & (onehot > 0)
    cap_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=dt) \
        * keep[..., None].astype(dt)                            # (S,g,k,E,C)
    disp = jnp.einsum("sgkec->sgec", cap_oh)                    # (S,g,E,C)
    xe = jnp.einsum("sgec,sgd->secd", disp, xg)                 # (S,E,C,d)
    h = jax.nn.silu(jnp.einsum(
        "secd,edf->secf", xe, fsdp_gather(p["wg"], cfg, -1).astype(dt))) \
        * jnp.einsum("secd,edf->secf", xe,
                     fsdp_gather(p["wu"], cfg, -1).astype(dt))
    ye = jnp.einsum("secf,efd->secd", h,
                    fsdp_gather(p["wd"], cfg, 1).astype(dt))    # (S,E,C,d)
    comb = jnp.einsum("sgkec,sgk->sgec", cap_oh,
                      topw.astype(dt))                          # (S,g,E,C)
    y = jnp.einsum("sgec,secd->sgd", comb, ye)
    # load-balancing aux loss (Switch-style)
    me = jnp.mean(gates, axis=(0, 1))
    ce = jnp.mean(onehot.sum(2), axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, T, d), aux


def apply_moe_dense(p, x, cfg: ModelConfig):
    """All-experts dense compute (decode / tiny T): weights × expert outs."""
    dt = dtype_of(cfg)
    B, T, d = x.shape
    xg = x.astype(dt)
    logits = (xg @ p["router"].astype(dt)).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, cfg.experts_per_tok)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    w = jnp.zeros_like(gates).at[
        jnp.arange(B)[:, None, None], jnp.arange(T)[None, :, None], topi
    ].set(topw)                                                  # (B,T,E)
    h = jax.nn.silu(jnp.einsum(
        "btd,edf->btef", xg, fsdp_gather(p["wg"], cfg, -1).astype(dt))) \
        * jnp.einsum("btd,edf->btef", xg,
                     fsdp_gather(p["wu"], cfg, -1).astype(dt))
    ye = jnp.einsum("btef,efd->bted", h,
                    fsdp_gather(p["wd"], cfg, 1).astype(dt))
    y = jnp.einsum("bte,bted->btd", w.astype(dt), ye)
    return y, jnp.zeros((), jnp.float32)
