"""Unified language-model assembly for all assigned architectures.

One ``init_params``/``loss_fn``/``decode_step`` triple covers:
  dense   — pre-norm GQA transformer (llama3/qwen3/deepseek/command-r)
  moe     — dense attention + top-k expert MLP (mixtral w/ SWA, grok-1)
  ssm     — Mamba2 SSD stack (attention-free)
  hybrid  — Mamba2 backbone + one *shared* attention block every k layers
            (zamba2; the shared block's params are reused, as in the paper)
  vlm     — dense backbone consuming stub patch embeddings + tokens
  encdec  — whisper backbone: bidirectional encoder over stub frame
            embeddings + causal decoder with cross-attention

Layers are scanned (stacked params) so compile time is O(1) in depth;
``cfg.remat`` selects the activation-checkpoint policy.  The CE loss is
computed in sequence chunks so the (T, vocab) logits are never materialised.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from . import layers as L
from . import ssm as S


# --------------------------------------------------------------------------- #
# init
# --------------------------------------------------------------------------- #

def _init_block(key, cfg: ModelConfig, kind: str):
    """One layer's params. kind: dense|moe|ssm|enc|dec."""
    ks = jax.random.split(key, 8)
    p = {}
    if kind in ("dense", "moe", "enc", "dec"):
        p["ln1"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["attn"] = L.init_attention(ks[0], cfg)
        p["ln2"] = jnp.ones((cfg.d_model,), jnp.float32)
        if kind == "moe":
            p["moe"] = L.init_moe(ks[1], cfg)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff)
        if kind == "dec" and cfg.n_enc_layers:
            p["ln_x"] = jnp.ones((cfg.d_model,), jnp.float32)
            p["xattn"] = L.init_attention(ks[2], cfg)
    elif kind == "ssm":
        p["ln1"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["ssm"] = S.init_mamba2(ks[0], cfg)
    return p


def _stack_init(key, cfg, kind, n):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_block(k, cfg, kind))(keys)


def init_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    p = {"embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02,
         "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}
    if not cfg.tie_embeddings:
        p["lm_head"] = L.init_linear(ks[1], cfg.d_model, cfg.vocab)
    if cfg.kind in ("dense", "vlm"):
        p["layers"] = _stack_init(ks[2], cfg, "dense", cfg.n_layers)
    elif cfg.kind == "moe":
        p["layers"] = _stack_init(ks[2], cfg, "moe", cfg.n_layers)
    elif cfg.kind == "ssm":
        p["layers"] = _stack_init(ks[2], cfg, "ssm", cfg.n_layers)
    elif cfg.kind == "hybrid":
        p["layers"] = _stack_init(ks[2], cfg, "ssm", cfg.n_layers)
        p["shared_attn"] = _init_block(ks[3], cfg, "dense")  # reused block
    elif cfg.kind == "encdec":
        p["enc_layers"] = _stack_init(ks[2], cfg, "enc", cfg.n_enc_layers)
        p["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["layers"] = _stack_init(ks[3], cfg, "dec", cfg.n_layers)
    else:
        raise ValueError(cfg.kind)
    return p


# --------------------------------------------------------------------------- #
# blocks
# --------------------------------------------------------------------------- #

def constrain_act(x, cfg: ModelConfig):
    """Pin activation batch sharding to the DP axes (no-op when unset).

    With cfg.seq_shard (Megatron sequence parallelism) the sequence dim is
    additionally sharded over the TP axis at block boundaries."""
    if not cfg.dp_axes:
        return x
    from jax.sharding import PartitionSpec as P
    axes = tuple(cfg.dp_axes) if len(cfg.dp_axes) > 1 else cfg.dp_axes[0]
    seq = (cfg.tp_axis if cfg.seq_shard and cfg.tp_size
           and x.ndim >= 3 and x.shape[1] % cfg.tp_size == 0 else None)
    spec = P(axes, seq, *([None] * (x.ndim - 2)))
    return jax.lax.with_sharding_constraint(x, spec)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)


def _dense_block(lp, x, cfg, positions, *, causal=True, window=None,
                 cross_kv=None, is_moe=False):
    x = constrain_act(x, cfg)
    h, _ = L.apply_attention(lp["attn"], L.rms_norm(x, lp["ln1"],
                                                    cfg.norm_eps,
                                                    cfg.norm_f32),
                             cfg, positions=positions, causal=causal,
                             window=window)
    x = x + h
    if cross_kv is not None:
        h, _ = L.apply_attention(lp["xattn"],
                                 L.rms_norm(x, lp["ln_x"], cfg.norm_eps, cfg.norm_f32),
                                 cfg, positions=positions, causal=False,
                                 cross_kv=cross_kv)
        x = x + h
    xn = L.rms_norm(x, lp["ln2"], cfg.norm_eps, cfg.norm_f32)
    if is_moe:
        h, aux = L.apply_moe(lp["moe"], xn, cfg)
    else:
        h, aux = L.apply_mlp(lp["mlp"], xn, cfg), jnp.zeros((), jnp.float32)
    return x + h, aux


def _ssm_block(lp, x, cfg):
    x = constrain_act(x, cfg)
    h, _ = S.apply_mamba2(lp["ssm"],
                          L.rms_norm(x, lp["ln1"], cfg.norm_eps,
                                     cfg.norm_f32), cfg)
    return x + h


# --------------------------------------------------------------------------- #
# forward (training / prefill)
# --------------------------------------------------------------------------- #

def _scan_or_unroll(cfg: ModelConfig, body, carry, stacked, n: int):
    """lax.scan over stacked layer params, or a Python unroll when
    cfg.scan_layers is False (used by the dry-run cost probes: XLA's
    cost_analysis counts a while-loop body once, so exact per-layer costs
    need unrolled HLO)."""
    if cfg.scan_layers:
        carry, _ = jax.lax.scan(body, carry, stacked)
        return carry
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], stacked)
        carry, _ = body(carry, lp)
    return carry


def forward_hidden(params, embeds, positions, cfg: ModelConfig,
                   enc_out=None):
    """embeds: (B,T,d) -> final hidden (B,T,d). Scan over layers."""
    x = embeds
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.kind in ("dense", "vlm", "moe"):
        is_moe = cfg.kind == "moe"

        def body(carry, lp):
            x, aux = carry
            x, a = _dense_block(lp, x, cfg, positions, causal=True,
                                window=cfg.window, is_moe=is_moe)
            return (x, aux + a), None
        body = _remat(cfg, body)
        (x, aux_total) = _scan_or_unroll(cfg, body, (x, aux_total),
                                         params["layers"], cfg.n_layers)
    elif cfg.kind == "ssm":
        def body(x, lp):
            return _ssm_block(lp, x, cfg), None
        body = _remat(cfg, body)
        x = _scan_or_unroll(cfg, body, x, params["layers"], cfg.n_layers)
    elif cfg.kind == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // every

        def body(x, lp):
            return _ssm_block(lp, x, cfg), None
        body = _remat(cfg, body)
        shared = params["shared_attn"]
        for g in range(n_groups):
            grp = jax.tree.map(lambda a: a[g * every:(g + 1) * every],
                               params["layers"])
            x = _scan_or_unroll(cfg, body, x, grp, every)
            x, _ = _dense_block(shared, x, cfg, positions, causal=True)
    elif cfg.kind == "encdec":
        def body(carry, lp):
            x, aux = carry
            kv = _cross_kv(lp, enc_out, cfg)
            x, a = _dense_block(lp, x, cfg, positions, causal=True,
                                cross_kv=kv)
            return (x, aux + a), None
        body = _remat(cfg, body)
        (x, aux_total) = _scan_or_unroll(cfg, body, (x, aux_total),
                                         params["layers"], cfg.n_layers)
    else:
        raise ValueError(cfg.kind)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.norm_f32)
    return x, aux_total


def _cross_kv(lp, enc_out, cfg):
    dt = L.dtype_of(cfg)
    B, Ts, d = enc_out.shape
    k = (enc_out.astype(dt) @ lp["xattn"]["wk"].astype(dt)).reshape(
        B, Ts, cfg.n_kv_heads, cfg.hd)
    v = (enc_out.astype(dt) @ lp["xattn"]["wv"].astype(dt)).reshape(
        B, Ts, cfg.n_kv_heads, cfg.hd)
    return (k, v)


def encode(params, frame_embeds, cfg: ModelConfig):
    """Whisper encoder over stub frame embeddings (B, enc_seq, d)."""
    B, T, d = frame_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x = frame_embeds

    def body(x, lp):
        x, _ = _dense_block(lp, x, cfg, positions, causal=False)
        return x, None
    body = _remat(cfg, body)
    x = _scan_or_unroll(cfg, body, x, params["enc_layers"],
                        cfg.n_enc_layers)
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps, cfg.norm_f32)


def embed_tokens(params, tokens, cfg: ModelConfig):
    return params["embed"].astype(L.dtype_of(cfg))[tokens]


def lm_head_weight(params, cfg: ModelConfig):
    return (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"])


# --------------------------------------------------------------------------- #
# loss (chunked cross-entropy; never materialises (T, vocab))
# --------------------------------------------------------------------------- #

def chunked_ce(hidden, w, labels, chunk=128):
    """hidden (B,T,d), w (d,V), labels int32 (B,T) with -1 = ignore."""
    B, T, d = hidden.shape
    c = min(chunk, T)
    nc = T // c
    h = hidden.reshape(B, nc, c, d).transpose(1, 0, 2, 3)
    y = labels.reshape(B, nc, c).transpose(1, 0, 2)

    def step(carry, inp):
        tot, cnt = carry
        hc, yc = inp
        logits = (hc @ w.astype(hc.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        yl = jnp.take_along_axis(
            logits, jnp.maximum(yc, 0)[..., None], axis=-1)[..., 0]
        mask = (yc >= 0).astype(jnp.float32)
        tot = tot + jnp.sum((lse - yl) * mask)
        cnt = cnt + jnp.sum(mask)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (h, y))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, batch, cfg: ModelConfig, aux_weight=0.01):
    """batch: dict(tokens, labels[, vis_embed | frames])."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    B, T = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    enc_out = None
    if cfg.kind == "vlm":
        vis = batch["vis_embed"].astype(x.dtype)       # (B, n_vis, d)
        x = jnp.concatenate([vis, x], axis=1)
        labels = jnp.concatenate(
            [jnp.full((B, vis.shape[1]), -1, labels.dtype), labels], axis=1)
    if cfg.kind == "encdec":
        enc_out = encode(params, batch["frames"].astype(x.dtype), cfg)
    Tt = x.shape[1]
    x = constrain_act(x, cfg)
    positions = jnp.broadcast_to(
        jnp.arange(Tt, dtype=jnp.int32)[None], (B, Tt))
    hidden, aux = forward_hidden(params, x, positions, cfg, enc_out=enc_out)
    hidden = constrain_act(hidden, cfg)
    loss = chunked_ce(hidden, lm_head_weight(params, cfg), labels)
    return loss + aux_weight * aux


# --------------------------------------------------------------------------- #
# serving: caches + decode step
# --------------------------------------------------------------------------- #

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16):
    """Stacked per-layer cache pytree for decode."""
    S_len = min(max_seq, cfg.window) if cfg.window else max_seq

    def kv():
        return {
            "k": jnp.zeros((batch, S_len, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((batch, S_len, cfg.n_kv_heads, cfg.hd), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }

    if cfg.kind in ("dense", "vlm", "moe"):
        return {"layers": jax.tree.map(
            lambda x: jnp.stack([x] * cfg.n_layers), kv())}
    if cfg.kind == "ssm":
        c = S.init_ssm_cache(cfg, batch)
        return {"layers": jax.tree.map(
            lambda x: jnp.stack([x] * cfg.n_layers), c)}
    if cfg.kind == "hybrid":
        c = S.init_ssm_cache(cfg, batch)
        return {
            "layers": jax.tree.map(
                lambda x: jnp.stack([x] * cfg.n_layers), c),
            "shared": jax.tree.map(
                lambda x: jnp.stack([x] * (cfg.n_layers
                                           // cfg.hybrid_attn_every)), kv()),
        }
    if cfg.kind == "encdec":
        return {"layers": jax.tree.map(
            lambda x: jnp.stack([x] * cfg.n_layers), kv()),
            "enc_out": jnp.zeros((batch, cfg.enc_seq, cfg.d_model), dtype)}
    raise ValueError(cfg.kind)


def _scan_or_unroll_cache(cfg: ModelConfig, body, x, stacked, caches,
                          n: int):
    """scan carrying x with (params, cache) xs and stacked cache ys; or
    unrolled equivalent (dry-run probes)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, x, (stacked, caches))
    new_caches = []
    for i in range(n):
        lp = jax.tree.map(lambda a: a[i], stacked)
        lc = jax.tree.map(lambda a: a[i], caches)
        x, nc = body(x, (lp, lc))
        new_caches.append(nc)
    stacked_nc = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_caches)
    return x, stacked_nc


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """One decode step. tokens: (B,1) int32; pos: scalar int32 (position).

    Returns (logits (B, vocab), new_cache)."""
    B = tokens.shape[0]
    x = embed_tokens(params, tokens, cfg)
    positions = jnp.full((B, 1), pos, jnp.int32)

    if cfg.kind in ("dense", "vlm", "moe", "encdec"):
        is_moe = cfg.kind == "moe"
        enc_out = cache.get("enc_out") if cfg.kind == "encdec" else None

        def body(x, inp):
            lp, lc = inp
            xn = L.rms_norm(x, lp["ln1"], cfg.norm_eps, cfg.norm_f32)
            h, nc = L.apply_attention(lp["attn"], xn, cfg,
                                      positions=positions, cache=lc,
                                      causal=True, window=cfg.window)
            x = x + h
            if enc_out is not None:
                kv = _cross_kv(lp, enc_out, cfg)
                h, _ = L.apply_attention(
                    lp["xattn"], L.rms_norm(x, lp["ln_x"], cfg.norm_eps, cfg.norm_f32),
                    cfg, positions=positions, causal=False, cross_kv=kv)
                x = x + h
            xn = L.rms_norm(x, lp["ln2"], cfg.norm_eps, cfg.norm_f32)
            if is_moe:
                h, _ = L.apply_moe_dense(lp["moe"], xn, cfg)
            else:
                h = L.apply_mlp(lp["mlp"], xn, cfg)
            return x + h, nc

        x, new_layer_cache = _scan_or_unroll_cache(
            cfg, body, x, params["layers"], cache["layers"], cfg.n_layers)
        new_cache = dict(cache)
        new_cache["layers"] = new_layer_cache
    elif cfg.kind == "ssm":
        def body(x, inp):
            lp, lc = inp
            xn = L.rms_norm(x, lp["ln1"], cfg.norm_eps, cfg.norm_f32)
            h, nc = S.apply_mamba2(lp["ssm"], xn, cfg, cache=lc)
            return x + h, nc
        x, new_layer_cache = _scan_or_unroll_cache(
            cfg, body, x, params["layers"], cache["layers"], cfg.n_layers)
        new_cache = {"layers": new_layer_cache}
    elif cfg.kind == "hybrid":
        every = cfg.hybrid_attn_every
        n_groups = cfg.n_layers // every

        def body(x, inp):
            lp, lc = inp
            xn = L.rms_norm(x, lp["ln1"], cfg.norm_eps, cfg.norm_f32)
            h, nc = S.apply_mamba2(lp["ssm"], xn, cfg, cache=lc)
            return x + h, nc

        new_layer_cache = []
        new_shared_cache = []
        shared = params["shared_attn"]
        for g in range(n_groups):
            grp = jax.tree.map(lambda a: a[g * every:(g + 1) * every],
                               params["layers"])
            grp_cache = jax.tree.map(lambda a: a[g * every:(g + 1) * every],
                                     cache["layers"])
            x, nc = _scan_or_unroll_cache(cfg, body, x, grp, grp_cache,
                                          every)
            new_layer_cache.append(nc)
            sc = jax.tree.map(lambda a: a[g], cache["shared"])
            xn = L.rms_norm(x, shared["ln1"], cfg.norm_eps, cfg.norm_f32)
            h, sc_new = L.apply_attention(shared["attn"], xn, cfg,
                                          positions=positions, cache=sc,
                                          causal=True)
            x = x + h
            h = L.apply_mlp(shared["mlp"],
                            L.rms_norm(x, shared["ln2"], cfg.norm_eps,
                                       cfg.norm_f32), cfg)
            x = x + h
            new_shared_cache.append(sc_new)
        new_cache = {
            "layers": jax.tree.map(
                lambda *xs: jnp.concatenate(xs, axis=0), *new_layer_cache),
            "shared": jax.tree.map(
                lambda *xs: jnp.stack(xs, axis=0), *new_shared_cache),
        }
    else:
        raise ValueError(cfg.kind)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps, cfg.norm_f32)
    logits = (x[:, 0] @ lm_head_weight(params, cfg).astype(x.dtype))
    return logits.astype(jnp.float32), new_cache
