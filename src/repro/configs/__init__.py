"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

ARCHS = {
    "llama3-8b": "llama3_8b",
    "qwen3-4b": "qwen3_4b",
    "deepseek-67b": "deepseek_67b",
    "command-r-35b": "command_r_35b",
    "zamba2-2.7b": "zamba2_2p7b",
    "mixtral-8x22b": "mixtral_8x22b",
    "grok-1-314b": "grok1_314b",
    "whisper-small": "whisper_small",
    "internvl2-26b": "internvl2_26b",
    "mamba2-2.7b": "mamba2_2p7b",
}


def get_config(name: str, smoke: bool = False):
    mod = importlib.import_module(f".{ARCHS[name]}", __name__)
    return mod.SMOKE if smoke else mod.CONFIG


def all_archs() -> list[str]:
    return list(ARCHS)
