"""deepseek-67b — llama-arch dense GQA [arXiv:2401.02954]."""
from ..models.config import ModelConfig
from .base import smoke_of

CONFIG = ModelConfig(
    name="deepseek-67b", kind="dense", n_layers=95, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22016, vocab=102400, head_dim=128,
    rope_theta=1e4,
)
SMOKE = smoke_of(CONFIG)
