"""llama3-8b — dense GQA transformer, 128k vocab [arXiv:2407.21783]."""
from ..models.config import ModelConfig
from .base import smoke_of

CONFIG = ModelConfig(
    name="llama3-8b", kind="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256, head_dim=128,
    rope_theta=500000.0,
)
SMOKE = smoke_of(CONFIG)
