"""mamba2-2.7b — attention-free SSD stack [arXiv:2405.21060]."""
from ..models.config import ModelConfig
from .base import smoke_of

CONFIG = ModelConfig(
    name="mamba2-2.7b", kind="ssm", n_layers=64, d_model=2560,
    n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
    ssm_state=128, ssm_heads=80, ssm_head_dim=64, ssm_expand=2,
)
SMOKE = smoke_of(CONFIG, n_heads=4, n_kv_heads=4)
