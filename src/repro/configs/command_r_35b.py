"""command-r-35b — dense GQA, no-bias, 256k vocab [hf:CohereForAI]."""
from ..models.config import ModelConfig
from .base import smoke_of

CONFIG = ModelConfig(
    name="command-r-35b", kind="dense", n_layers=40, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22528, vocab=256000, head_dim=128,
    rope_theta=8e6,
)
SMOKE = smoke_of(CONFIG)
