"""qwen3-4b — dense GQA with qk_norm, tied embeddings [hf:Qwen/Qwen3-8B]."""
from ..models.config import ModelConfig
from .base import smoke_of

CONFIG = ModelConfig(
    name="qwen3-4b", kind="dense", n_layers=36, d_model=2560,
    n_heads=32, n_kv_heads=8, d_ff=9728, vocab=151936, head_dim=128,
    qk_norm=True, tie_embeddings=True, rope_theta=1e6,
)
SMOKE = smoke_of(CONFIG)
