"""Config helpers: shape grid shared by all LM-family archs + smoke reducer."""
from __future__ import annotations

import dataclasses

from ..models.config import ModelConfig

# The assigned input-shape set (seq_len, global_batch, mode).
SHAPES = {
    "train_4k": dict(seq=4096, batch=256, mode="train"),
    "prefill_32k": dict(seq=32768, batch=32, mode="prefill"),
    "decode_32k": dict(seq=32768, batch=128, mode="decode"),
    "long_500k": dict(seq=524288, batch=1, mode="decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """long_500k only for sub-quadratic archs (SSM/hybrid/SWA)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        names.append("long_500k")
    return names


def smoke_of(cfg: ModelConfig, **over) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    d = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=max(
            1, 4 * cfg.n_kv_heads // max(cfg.n_heads, 1)),
        d_ff=128, vocab=512, head_dim=16,
    )
    if cfg.kind == "moe":
        d.update(n_experts=4, experts_per_tok=2)
    if cfg.kind in ("ssm", "hybrid"):
        d.update(ssm_state=16, ssm_heads=8, ssm_head_dim=16, ssm_chunk=16,
                 d_model=64)  # d_in = 128 = 8*16
    if cfg.kind == "hybrid":
        d.update(n_layers=4, hybrid_attn_every=2)
    if cfg.kind == "encdec":
        d.update(n_enc_layers=2, enc_seq=32)
    if cfg.kind == "vlm":
        d.update(n_vis_tokens=8)
    if cfg.window is not None:
        d.update(window=32)
    d.update(over)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **d)
