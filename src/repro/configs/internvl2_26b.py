"""internvl2-26b — InternLM2 backbone; InternViT frontend is a STUB:
input_specs provides precomputed (B, 256, d) patch embeddings [2404.16821]."""
from ..models.config import ModelConfig
from .base import smoke_of

CONFIG = ModelConfig(
    name="internvl2-26b", kind="vlm", n_layers=48, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=92553, head_dim=128,
    n_vis_tokens=256,
)
SMOKE = smoke_of(CONFIG)
