"""zamba2-2.7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

54 Mamba2 layers with ONE shared (param-reused) attention+MLP block applied
every 6 layers (9 invocations). GQA kv=32 == MHA per the assignment.
"""
from ..models.config import ModelConfig
from .base import smoke_of

CONFIG = ModelConfig(
    name="zamba2-2.7b", kind="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, ssm_heads=80, ssm_head_dim=64, ssm_expand=2,
    hybrid_attn_every=6,
)
SMOKE = smoke_of(CONFIG)
