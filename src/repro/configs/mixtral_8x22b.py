"""mixtral-8x22b — 8-expert top-2 MoE with sliding-window attn [2401.04088]."""
from ..models.config import ModelConfig
from .base import smoke_of

CONFIG = ModelConfig(
    name="mixtral-8x22b", kind="moe", n_layers=56, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=16384, vocab=32768, head_dim=128,
    n_experts=8, experts_per_tok=2, window=4096, rope_theta=1e6,
)
SMOKE = smoke_of(CONFIG)
