"""whisper-small — enc-dec backbone; conv frontend is a STUB: input_specs
provides precomputed (B, 1500, d) frame embeddings [arXiv:2212.04356]."""
from ..models.config import ModelConfig
from .base import smoke_of

CONFIG = ModelConfig(
    name="whisper-small", kind="encdec", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865, head_dim=64,
    n_enc_layers=12, enc_seq=1500,
)
SMOKE = smoke_of(CONFIG)
