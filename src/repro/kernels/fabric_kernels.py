"""Pallas kernels for the fabric scan body's three dominant stages.

The ``lax.scan`` body in ``sim/fabric.py`` spends its time in three
gather/scatter-heavy stages: the fused queue-ring service + enqueue step
(ring-head pop, occupancy drop/ECN decisions, two-pass rank + flat ring
scatter), the sort-free enqueue ranker, and the per-flow protocol
transitions (``on_ack`` / ``on_timer`` / ``next_packet``, optionally over
a gathered ``active_cap`` slate).  This module provides those stages as
Pallas kernels, selected by ``FabricConfig.kernel_backend``:

  * ``"jnp"`` (default) — no Pallas: the fabric calls the stage *core*
    functions inline and XLA fuses them as before.
  * ``"pallas"`` — compiled Pallas kernels (real TPU/GPU backends).
  * ``"pallas_interpret"`` — Pallas interpret mode: the kernel bodies run
    as ordinary XLA ops on any backend (CPU CI), preserving the kernel
    call structure and ref semantics without a Mosaic/Triton compile.

Bit-exactness strategy
----------------------
The serve/enqueue and transition kernels are *fused-core* kernels: the
fabric builds one core function per stage (closing over its static dims
and protocol dispatch) and either calls it inline (jnp backend) or hands
it to :func:`fused_stage_kernel`, which runs the SAME core inside a
single-block ``pallas_call`` — all operands loaded from refs up front,
all results stored back at the end.  Both paths therefore execute the
same math on the same operands, so they are bit-exact by construction;
the differential-fuzz suite (``tests/test_fuzz_parity.py``) and the
per-kernel parity tests (``tests/test_fabric_kernels.py``) gate it.

The ranker is a genuinely independent second implementation — a
sequential block sweep carrying a running per-queue count table instead
of the jnp path's scatter-add table + exclusive cumsum + batched tril —
and is validated against the O(M^2) oracle and the argsort reference in
``tests/test_rank_active.py`` / ``tests/test_fabric_kernels.py``.
Integer ranks are deterministic, so algorithm independence still yields
bit-identical results.

Compiled-mode caveats (see docs/performance.md "Kernel backends"): the
fused-stage kernels are single-block — every operand must fit the
target's kernel memory (VMEM on TPU) — and the transition kernel traces
protocol ``lax.cond`` / segment ops inside the kernel body, which Mosaic
supports only on recent TPU generations.  Interpret mode has neither
restriction and is the only mode exercised on CPU CI.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Block width of the ranker kernel's sequential sweep (matches the jnp
#: ranker's ``_RANK_CHUNK``: intra-block work is a dense CHUNK x CHUNK
#: strictly-lower-triangle count).
RANK_CHUNK = 256


def iota1(n: int) -> jax.Array:
    """1-D int32 iota that is legal inside TPU Pallas kernel bodies
    (TPU requires >= 2-D iota; this broadcasts then squeezes)."""
    return jax.lax.broadcasted_iota(jnp.int32, (n, 1), 0)[:, 0]


# --------------------------------------------------------------------------- #
# Kernel 2: the sort-free enqueue ranker
# --------------------------------------------------------------------------- #

def rank_in_queue_core(qid: jax.Array, flag: jax.Array, n_queues: int,
                       chunk: int = RANK_CHUNK) -> jax.Array:
    """Rank of each candidate among flag-set candidates of the same queue
    (candidate-index order), ``-1`` at non-flagged entries — the
    ``fabric._rank_in_queue`` contract as one kernel-safe computation.

    Single sequential sweep over ``chunk``-wide blocks carrying a running
    per-queue count table: each block reads its per-queue starting ranks
    from the table (the incremental equivalent of the jnp path's
    scatter-add table + exclusive block cumsum), resolves intra-block
    order with a dense strictly-lower-triangle same-queue count, and
    scatter-adds its own flagged counts back into the table.  Runs as-is
    inside other kernel bodies (the fused serve/enqueue kernel inlines it
    for candidate counts past the all-pairs cutoff).
    """
    m = qid.shape[0]
    if m == 0:
        return jnp.zeros((0,), jnp.int32)
    c = int(chunk)
    qid = qid.astype(jnp.int32)
    pad = (-m) % c
    if pad:
        qid = jnp.concatenate(
            [qid, jnp.full((pad,), n_queues, jnp.int32)])
        flag = jnp.concatenate([flag, jnp.zeros((pad,), bool)])
    nb = (m + pad) // c
    qc = qid.reshape(nb, c)
    fc = flag.reshape(nb, c)
    tril = (jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
            < jax.lax.broadcasted_iota(jnp.int32, (c, c), 0))

    def block(b, carry):
        counts, out = carry
        qb = jax.lax.dynamic_index_in_dim(qc, b, 0, keepdims=False)
        fb = jax.lax.dynamic_index_in_dim(fc, b, 0, keepdims=False)
        base = counts[qb]
        intra = jnp.sum((qb[:, None] == qb[None, :])
                        & fb[None, :] & tril, axis=1).astype(jnp.int32)
        out = jax.lax.dynamic_update_slice(
            out, jnp.where(fb, base + intra, -1), (b * c,))
        counts = counts.at[jnp.where(fb, qb, n_queues)].add(
            fb.astype(jnp.int32))
        return counts, out

    _, out = jax.lax.fori_loop(
        0, nb, block, (jnp.zeros((n_queues + 1,), jnp.int32),
                       jnp.zeros((nb * c,), jnp.int32)))
    return out[:m]


def rank_in_queue_kernel(qid: jax.Array, flag: jax.Array, n_queues: int,
                         *, chunk: int = RANK_CHUNK,
                         interpret: bool = True) -> jax.Array:
    """The ranker as a standalone single ``pallas_call`` (the three XLA
    ops of the jnp path — scatter-add table, exclusive cumsum, batched
    tril resolve — collapsed into one kernel)."""
    if qid.shape[0] == 0:
        return jnp.zeros((0,), jnp.int32)

    def kernel(q_ref, f_ref, o_ref):
        o_ref[...] = rank_in_queue_core(q_ref[...], f_ref[...],
                                        n_queues, chunk)

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((qid.shape[0],), jnp.int32),
        interpret=interpret)(
        jnp.asarray(qid, jnp.int32), jnp.asarray(flag, bool))


# --------------------------------------------------------------------------- #
# Kernels 1 & 3: fused-core stages (serve+enqueue, per-flow transitions)
# --------------------------------------------------------------------------- #

def fused_stage_kernel(core, args, *, interpret: bool = True):
    """Run ``core(*args)`` as one single-block ``pallas_call``.

    ``args`` is an arbitrary pytree-per-argument tuple (protocol flow
    states, queue rings, lane vectors, traced scalars); every leaf
    becomes a kernel input ref, scalars ride as shape-(1,) arrays.  The
    kernel body loads all refs, rebuilds the argument pytrees, calls the
    SAME core function the jnp backend calls inline, and stores the
    flattened result pytree into the output refs — so the Pallas and jnp
    paths are one implementation and differ only in execution substrate.
    Output shapes/dtypes come from ``jax.eval_shape`` on the core, which
    keeps this wrapper agnostic to the protocol's state pytrees.
    """
    flat, treedef = jax.tree.flatten(args)
    flat = [jnp.asarray(x) for x in flat]
    in_scalar = [x.ndim == 0 for x in flat]
    ins = [x[None] if s else x for x, s in zip(flat, in_scalar)]

    out_struct = jax.eval_shape(
        lambda *xs: core(*jax.tree.unflatten(treedef, xs)), *flat)
    out_leaves, out_tree = jax.tree.flatten(out_struct)
    out_scalar = [s.shape == () for s in out_leaves]
    out_shape = tuple(
        jax.ShapeDtypeStruct((1,) if sc else s.shape, s.dtype)
        for s, sc in zip(out_leaves, out_scalar))
    n_in = len(ins)

    def kernel(*refs):
        vals = [r[...] for r in refs[:n_in]]
        vals = [v[0] if s else v for v, s in zip(vals, in_scalar)]
        outs = core(*jax.tree.unflatten(treedef, vals))
        for ref, leaf, sc in zip(refs[n_in:], jax.tree.leaves(outs),
                                 out_scalar):
            ref[...] = leaf[None] if sc else leaf

    res = pl.pallas_call(kernel, out_shape=out_shape,
                         interpret=interpret)(*ins)
    if not isinstance(res, (tuple, list)):
        res = (res,)
    res = [r[0] if sc else r for r, sc in zip(res, out_scalar)]
    return jax.tree.unflatten(out_tree, res)


def serve_enqueue_kernel(core, args, *, interpret: bool = True):
    """Kernel 1: fused queue-ring service + two-pass enqueue (ring-head
    pop, ECN mark, occupancy drop/accept, rank + flat ring scatter,
    departure-time lane update) as one kernel call."""
    return fused_stage_kernel(core, args, interpret=interpret)


def flow_transition_kernel(core, args, *, interpret: bool = True):
    """Kernel 3: per-flow protocol transitions (``on_ack`` / ``on_timer``
    / ``next_packet`` + NIC round-robin arbitration) as one kernel call.
    The active-set variant gathers the ``active_cap`` lane slate from the
    [N] state and scatters it back INSIDE the kernel, so the
    intermediate [A]-shaped flow pytrees never materialize in HBM."""
    return fused_stage_kernel(core, args, interpret=interpret)
