"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (batch*heads, n_chunks) — chunks are the minor (sequential) grid dim,
so the inter-chunk state (N, P) is carried in VMEM scratch, exactly the
hardware-resident recurrence of the SSD algorithm.  All intra-chunk work is
(L,N)/(L,L)/(L,P) matmuls with L = chunk (MXU-aligned at 128).

Shapes: x (B,T,H,P) -> per-grid block (L,P); dt (B,T,H) -> (L,); B̂/Ĉ
(B,T,N) shared across heads -> (L,N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *,
                chunk):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    L = chunk
    x = x_ref[0].astype(jnp.float32)          # (L, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (L,)
    a = a_ref[0, 0]                           # scalar A_h (negative)
    b = b_ref[0].astype(jnp.float32)          # (L, N)
    c = c_ref[0].astype(jnp.float32)          # (L, N)

    lam = dt * a                              # (L,) log decay
    cs = jnp.cumsum(lam)                      # (L,)
    dtx = dt[:, None] * x                     # (L, P)

    # intra-chunk: y_i = sum_{j<=i} (C_i . B_j) exp(cs_i - cs_j) dtx_j
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L,L)
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    diff = cs[:, None] - cs[None, :]
    decay = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    y = jax.lax.dot(cb * decay, dtx, preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    state = state_scr[...]                    # (N, P)
    y += jnp.exp(cs)[:, None] * jax.lax.dot(
        c, state, preferred_element_type=jnp.float32)

    # state update for the next chunk
    w = jnp.exp(cs[-1] - cs)                  # (L,)
    state_scr[...] = jnp.exp(cs[-1]) * state + jax.lax.dot_general(
        b * w[:, None], dtx, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, A, B_, C_, *, chunk=128, interpret=True):
    """x (B,T,H,P), dt (B,T,H), A (H,), B_/C_ (B,T,N) -> y (B,T,H,P)."""
    Bb, T, H, P = x.shape
    N = B_.shape[-1]
    L = min(chunk, T)
    assert T % L == 0, (T, L)
    nc = T // L

    # (B,T,H,P) -> (B*H, T, P)
    x_r = x.transpose(0, 2, 1, 3).reshape(Bb * H, T, P)
    dt_r = dt.transpose(0, 2, 1).reshape(Bb * H, T, 1)
    a_r = jnp.tile(A[None, :], (Bb, 1)).reshape(Bb * H, 1)

    grid = (Bb * H, nc)
    x_spec = pl.BlockSpec((1, L, P), lambda bh, ic: (bh, ic, 0))
    dt_spec = pl.BlockSpec((1, L, 1), lambda bh, ic: (bh, ic, 0))
    a_spec = pl.BlockSpec((1, 1), lambda bh, ic: (bh, 0))
    bc_spec = pl.BlockSpec((1, L, N), lambda bh, ic: (bh // H, ic, 0))
    y_spec = pl.BlockSpec((1, L, P), lambda bh, ic: (bh, ic, 0))

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=L),
        grid=grid,
        in_specs=[x_spec, dt_spec, a_spec, bc_spec, bc_spec],
        out_specs=y_spec,
        out_shape=jax.ShapeDtypeStruct((Bb * H, T, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x_r, dt_r, a_r, B_, C_)
    return out.reshape(Bb, H, T, P).transpose(0, 2, 1, 3)
