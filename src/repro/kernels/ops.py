"""jit'd public wrappers for the Pallas kernels.

Models call these; layouts are converted from the model's (B, T, H, hd)
convention to the kernels' (B, H, T, hd).  ``interpret`` defaults to True
(CPU validation); set REPRO_PALLAS_COMPILE=1 on real TPUs.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import ssd_scan as _ssd

_INTERPRET = os.environ.get("REPRO_PALLAS_COMPILE", "0") != "1"


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset"))
def flash_attention(q, k, v, q_pos=None, k_pos=None, *, causal=True,
                    window=None, q_offset=0):
    """q: (B,T,H,hd), k/v: (B,S,K,hd) — model layout. Returns same layout."""
    out = _fa.flash_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        q_offset=q_offset, interpret=_INTERPRET)
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, B_, C_, chunk=128):
    """Mamba2 SSD: x (B,T,H,P), dt (B,T,H), A (H,), B_/C_ (B,T,N)."""
    return _ssd.ssd_scan(x, dt, A, B_, C_, chunk=chunk,
                         interpret=_INTERPRET)
