"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose vs these)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=None, q_offset=0):
    """q: (B,H,Tq,hd); k,v: (B,K,Tk,hd). Materialised-softmax reference."""
    B, H, Tq, hd = q.shape
    _, K, Tk, _ = k.shape
    G = H // K
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / math.sqrt(hd)
    q_pos = jnp.arange(Tq) + q_offset
    k_pos = jnp.arange(Tk)
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vv.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x, dt, A, B_, C_):
    """Sequential (token-by-token) SSD recurrence — the exact oracle.

    x (B,T,H,P), dt (B,T,H), A (H,), B_/C_ (B,T,N). Returns (y, state)."""
    Bb, T, H, P = x.shape
    N = B_.shape[-1]
    f32 = jnp.float32

    def step(state, inp):
        xt, dtt, bt, ct = inp
        a = jnp.exp(dtt * A)                      # (B,H)
        dtx = dtt[..., None] * xt                 # (B,H,P)
        state = a[:, :, None, None] * state + jnp.einsum(
            "bn,bhp->bhnp", bt, dtx)
        y = jnp.einsum("bn,bhnp->bhp", ct, state)
        return state, y

    xs = (x.transpose(1, 0, 2, 3).astype(f32),
          dt.transpose(1, 0, 2).astype(f32),
          B_.transpose(1, 0, 2).astype(f32),
          C_.transpose(1, 0, 2).astype(f32))
    s0 = jnp.zeros((Bb, H, N, P), f32)
    state, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), state
