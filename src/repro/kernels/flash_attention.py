"""Pallas TPU flash attention (GQA, causal, sliding-window).

TPU-native tiling: the grid is (batch*q_heads, q_blocks, kv_blocks) with the
kv dimension innermost — TPU grids execute sequentially per core, so the
online-softmax state (m, l, acc) lives in VMEM scratch and is carried
across kv steps; the output block is written on the last kv step.  Block
shapes are MXU-aligned (multiples of 128 on the matmul dims).  Fully-masked
kv blocks (beyond the causal frontier / outside the sliding window) are
skipped with pl.when.

Validated against kernels/ref.py in interpret mode (tests/test_kernels.py);
on real TPUs drop interpret=True.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale, block_q, block_k, seq_k, causal, window, q_offset):
    """One (q_block, kv_block) cell. Scratch carries online-softmax state."""
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    # block-level early-out: skip fully-masked kv blocks
    first_q = iq * block_q + q_offset
    last_q = first_q + block_q - 1
    first_k = ik * block_k
    live = True
    if causal:
        live = jnp.asarray(first_k <= last_q)
    if window is not None:
        live = jnp.logical_and(live, (ik + 1) * block_k - 1 > first_q - window)

    @pl.when(live)
    def _compute():
        # zero the rows of a ragged tail block: OOB block reads are
        # implementation-defined (NaN in interpret mode) and 0*NaN = NaN
        # would leak through the p@V dot even where p == 0.
        row_ok = (ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, 1), 0)) < seq_k
        q = q_ref[0].astype(jnp.float32)             # (block_q, hd)
        k = jnp.where(row_ok, k_ref[0].astype(jnp.float32), 0.0)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = k_pos < seq_k
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        m_scr[...] = m_new
        v = jnp.where(row_ok, v_ref[0].astype(jnp.float32), 0.0)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                    block_q=128, block_k=128, interpret=True):
    """q: (B, H, Tq, hd); k, v: (B, K, Tk, hd). Returns (B, H, Tq, hd).

    ``q_offset`` positions the q block absolutely (decode / chunked prefill:
    q_pos = q_offset + i).  GQA: q head h reads kv head h // (H // K).
    """
    B, H, Tq, hd = q.shape
    _, K, Tk, _ = k.shape
    assert H % K == 0
    group = H // K
    scale = 1.0 / math.sqrt(hd)

    bq = min(block_q, Tq)
    bk = min(block_k, Tk)
    nq = pl.cdiv(Tq, bq)
    nk = pl.cdiv(Tk, bk)

    q_r = q.reshape(B * H, Tq, hd)
    grid = (B * H, nq, nk)

    q_spec = pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0))
    # GQA mapping: bh = b * H + h  ->  kv row b * K + h // group
    kv_spec = pl.BlockSpec(
        (1, bk, hd),
        lambda bh, iq, ik: ((bh // H) * K + (bh % H) // group, ik, 0))
    o_spec = pl.BlockSpec((1, bq, hd), lambda bh, iq, ik: (bh, iq, 0))

    kernel = functools.partial(
        _fa_kernel, scale=scale, block_q=bq, block_k=bk, seq_k=Tk,
        causal=causal, window=window, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q_r, k.reshape(B * K, Tk, hd), v.reshape(B * K, Tk, hd))
    return out.reshape(B, H, Tq, hd)
