"""Transport-aware collective performance model (paper -> framework bridge).

The dry-run extracts per-step collective bytes from compiled HLO; the event
simulator measures what fraction of link bandwidth each transport actually
sustains under its load-balancing behaviour (ECMP hash collisions vs
adaptive spray).  This module combines the two: the *collective roofline
term* of a training step on the production mesh, under RoCEv2 vs STrack.

Two fabric tiers (DESIGN.md §2):
  * intra-pod ICI (torus, deterministic routing) — transport-independent;
  * inter-pod DCN/Ethernet — ECMP-multipath, where STrack applies.

The inter-pod traffic of the multi-pod mesh is the gradient all-reduce over
the "pod" axis; its time scales with 1/efficiency(transport).
"""
from __future__ import annotations

import dataclasses

from ..launch.roofline import LINK_BW


@dataclasses.dataclass(frozen=True)
class TransportEfficiency:
    """Sustained goodput fraction of nominal bandwidth (from sim/events.py
    benchmarks: permutation workload, full-bisection fat-tree)."""

    name: str
    fabric_efficiency: float     # multipath fabric utilization
    incast_efficiency: float     # last-hop utilization under moderate incast

    def effective_bw(self, nominal: float) -> float:
        return nominal * self.fabric_efficiency


def measure_efficiency(transport: str, n_tor: int = 8, hosts_per_tor: int = 8,
                       msg_bytes: float = 2 * 2 ** 20, seed: int = 0,
                       **sim_kw) -> TransportEfficiency:
    """Run a permutation workload and convert max-FCT to goodput fraction."""
    from ..core.params import NetworkSpec
    from ..sim.events import NetSim
    from ..sim.topology import full_bisection
    from ..sim.workloads import permutation_scenario, run_scenario_on_sim

    net = NetworkSpec()
    topo = full_bisection(n_tor, hosts_per_tor)
    sim = NetSim(topo, net, transport=transport, seed=seed, **sim_kw)
    sc = permutation_scenario(topo, msg_bytes, net=net)
    res = run_scenario_on_sim(sim, sc, until=5e5)
    ideal = msg_bytes / net.rate_Bpus + net.base_rtt_us
    eff = min(1.0, ideal / res["max_fct"]) if res["max_fct"] else 0.0
    return TransportEfficiency(name=transport, fabric_efficiency=eff,
                               incast_efficiency=eff)


def collective_term_with_transport(collective_bytes_per_dev: float,
                                   inter_pod_bytes_per_dev: float,
                                   eff: TransportEfficiency,
                                   link_bw: float = LINK_BW,
                                   dcn_bw: float = 50e9) -> dict:
    """Split the collective term into ICI (intra-pod) + DCN (inter-pod,
    transport-scaled) components."""
    ici_bytes = max(collective_bytes_per_dev - inter_pod_bytes_per_dev, 0.0)
    t_ici = ici_bytes / link_bw
    t_dcn = inter_pod_bytes_per_dev / eff.effective_bw(dcn_bw)
    return {
        "ici_s": t_ici,
        "dcn_s": t_dcn,
        "total_s": t_ici + t_dcn,
        "transport": eff.name,
        "fabric_efficiency": eff.fabric_efficiency,
    }
