"""Collective-algorithm trace generators (paper Section 4.3).

Each generator emits a list of ``Message`` records with dependency edges
exactly as the paper describes: "messages from later steps are sent only
after messages in previous steps are received".  Messages are chunked (the
paper uses 128 KB chunks "to utilize the pipeline") — chunk c of step s
depends on chunk c of step s-1, which pipelines the steps.

Algorithms: Ring / DoubleBinaryTree / HalvingDoubling AllReduce, and
windowed AlltoAll (sequenced (n+1), (n+2), ... with a bounded number of
active connections, the paper's incast-avoidance ordering).
"""
from __future__ import annotations

import math

from ..sim.workloads import Message


def _flat(deps):
    return [x for e in deps for x in (e if isinstance(e, list) else [e])]


class _Trace:
    def __init__(self, group):
        self.msgs: list[Message] = []
        self.group = group

    def add(self, src, dst, size, deps=None, chunk=None):
        """Add one message (optionally chunked); returns its msg ids.

        ``deps`` elements may be ints or lists of ids (a chunked parent).
        A chunked message's chunk c depends on the parent's chunk c when
        chunk counts match (step pipelining), else on all parent chunks."""
        deps = list(deps or [])
        if chunk is None or size <= chunk:
            m = Message(mid=len(self.msgs), src=src, dst=dst, size=size,
                        deps=_flat(deps), group=self.group)
            self.msgs.append(m)
            return [m.mid]
        n = math.ceil(size / chunk)
        ids = []
        for c in range(n):
            sz = min(chunk, size - c * chunk)
            dd = []
            for e in deps:
                if isinstance(e, list) and len(e) == n:
                    dd.append(e[c])          # pipeline chunk-to-chunk
                elif isinstance(e, list):
                    dd.extend(e)
                else:
                    dd.append(e)
            m = Message(mid=len(self.msgs), src=src, dst=dst, size=sz,
                        deps=dd, group=self.group)
            self.msgs.append(m)
            ids.append(m.mid)
        return ids


def ring_allreduce(n: int, total_bytes: float, group: int = 0,
                   chunk: float = 128 * 1024) -> list[Message]:
    """Ring: reduce-scatter (n-1 steps) + all-gather (n-1 steps)."""
    tr = _Trace(group)
    seg = total_bytes / n
    prev: dict[int, list] = {r: None for r in range(n)}
    for step in range(2 * (n - 1)):
        new_prev = {}
        for r in range(n):
            deps = [prev[(r - 1) % n]] if prev[(r - 1) % n] else []
            new_prev[r] = tr.add(r, (r + 1) % n, seg, deps=deps, chunk=chunk)
        prev = new_prev
    return tr.msgs


def _btree_children(n, root_shift=0):
    """Complete binary tree over ranks (heap layout), shifted."""
    par = {}
    for i in range(n):
        p = (i - 1) // 2 if i > 0 else None
        par[(i + root_shift) % n] = ((p + root_shift) % n
                                     if p is not None else None)
    return par


def dbt_allreduce(n: int, total_bytes: float, group: int = 0,
                  chunk: float = 128 * 1024) -> list[Message]:
    """DoubleBinaryTree: two trees, half the payload each; reduce to root
    then broadcast (the 2:1 incast pattern the paper highlights)."""
    tr = _Trace(group)
    half = total_bytes / 2
    for shift in (0, n // 2):
        parent = _btree_children(n, shift)
        children: dict[int, list[int]] = {r: [] for r in range(n)}
        for c, p in parent.items():
            if p is not None:
                children[p].append(c)
        # reduce: leaves up
        up_ids: dict[int, list] = {}

        def reduce_up(r):
            deps = []
            for c in children[r]:
                if c not in up_ids:
                    reduce_up(c)
                deps.append(up_ids[c])
            p = parent[r]
            if p is not None:
                up_ids[r] = tr.add(r, p, half, deps=deps, chunk=chunk)
        root = next(r for r, p in parent.items() if p is None)
        for r in range(n):
            if r != root and r not in up_ids:
                reduce_up(r)
        # broadcast: root down
        down_ids: dict[int, list] = {root: up_ids.get(root) or []}

        def bcast(r, dep):
            for c in children[r]:
                down_ids[c] = tr.add(r, c, half, deps=dep, chunk=chunk)
                bcast(c, down_ids[c])
        root_dep = []
        for c in children[root]:
            root_dep.append(up_ids[c])
        bcast(root, [d for ids in root_dep for d in
                     (ids if isinstance(ids, list) else [ids])]
              if root_dep else [])
    return tr.msgs


def hd_allreduce(n: int, total_bytes: float, group: int = 0,
                 chunk: float = 128 * 1024) -> list[Message]:
    """HalvingDoubling: log2(n) RS rounds + log2(n) AG rounds (XOR pairs)."""
    assert n & (n - 1) == 0, "HD needs power-of-two ranks"
    tr = _Trace(group)
    rounds = int(math.log2(n))
    prev = {r: None for r in range(n)}
    size = total_bytes / 2
    for k in range(rounds):                     # reduce-scatter, halving
        new_prev = {}
        for r in range(n):
            peer = r ^ (1 << k)
            deps = [prev[r]] if prev[r] else []
            new_prev[r] = tr.add(r, peer, size, deps=deps, chunk=chunk)
        prev = new_prev
        size /= 2
    size *= 2
    for k in reversed(range(rounds)):           # all-gather, doubling
        new_prev = {}
        for r in range(n):
            peer = r ^ (1 << k)
            deps = [prev[r]] if prev[r] else []
            new_prev[r] = tr.add(r, peer, size, deps=deps, chunk=chunk)
        prev = new_prev
        size *= 2
    return tr.msgs


def alltoall(n: int, total_bytes: float, group: int = 0,
             window: int = 32, chunk: float = 128 * 1024
             ) -> list[Message]:
    """AlltoAll, sequenced (n+1),(n+2),... with ≤ ``window`` active
    connections per sender/receiver (paper's incast-ordering)."""
    tr = _Trace(group)
    per = total_bytes / max(n - 1, 1)
    pending: dict[int, list] = {r: [] for r in range(n)}
    for j in range(1, n):
        for r in range(n):
            dst = (r + j) % n
            deps = []
            if j > window:
                deps = pending[r][j - window - 1]
            ids = tr.add(r, dst, per, deps=deps, chunk=chunk)
            pending[r].append(ids)
    return tr.msgs


ALGOS = {"ring": ring_allreduce, "dbt": dbt_allreduce, "hd": hd_allreduce,
         "a2a": alltoall}


def multi_job(algo: str, n_jobs: int, ranks_per_job: int, n_hosts: int,
              collective_bytes: float, seed: int = 0, hosts=None, **kw):
    """The paper's multi-job setup: ``n_jobs`` identical collectives,
    each group randomly placed on the cluster. Returns (messages,
    placement) where placement maps global rank-id -> host.

    ``hosts`` pins the placement instead of shuffling: an explicit host
    list (rank ``j * ranks_per_job + r`` lands on ``hosts[...]``), so a
    caller can reuse one placement across repeated generations — the
    multi-tenant traffic generator keeps each job's placement stable
    across soak epochs this way.

    ``workloads.collective_scenario`` wraps this into a backend-agnostic
    :class:`~repro.sim.workloads.Scenario` (hosts resolved, deps kept)."""
    import random
    if hosts is None:
        rng = random.Random(seed)
        hosts = list(range(n_hosts))
        rng.shuffle(hosts)
    else:
        hosts = list(hosts)
        assert len(hosts) >= n_jobs * ranks_per_job, \
            "pinned placement smaller than the job's rank count"
    assert n_jobs * ranks_per_job <= n_hosts
    msgs: list[Message] = []
    placement: dict[int, int] = {}
    gen = ALGOS[algo]
    for j in range(n_jobs):
        sub = gen(ranks_per_job, collective_bytes, group=j, **kw)
        base = len(msgs)
        rank_base = j * ranks_per_job
        for m in sub:
            msgs.append(Message(
                mid=m.mid + base, src=m.src + rank_base,
                dst=m.dst + rank_base, size=m.size,
                deps=tuple(d + base for d in m.deps), group=j))
        for r in range(ranks_per_job):
            placement[rank_base + r] = hosts[rank_base + r]
    return msgs, placement
