"""Time-varying fault injection for the fabric and the events oracle.

The chaos subsystem (docs/robustness.md) models three fault classes as
*fixed-shape program data* — entry counts are static (they reach the
program cache key), every time/probability value is traced, so one
compiled program replays any schedule of the same shape:

* **flaps** — a link is down for ticks ``[t0, t1)``.  Packets served by
  a down link are blackholed (they left the buffer and never arrive);
  NIC injection onto a down host uplink is blackholed after the flow
  commits its send state, so senders discover the loss the same way
  real hardware does: silence, then RTO / SACK / go-back-N.
* **degrades** — a ToR↔spine link serves at a fractional credit
  ``c ∈ (0, 1]``: inside the window the queue may pop its head only on
  ticks where ``floor((t+1)·c·256)/256`` advances — a deterministic
  duty cycle realising the fractional rate with no extra state.
* **corruption** — each packet served by the link is dropped with
  probability ``p``, drawn from the same counter-based splitmix64
  generator as ``sim/traffic.py`` keyed by ``(seed, link-row, tick,
  psn)`` — replayable and backend-independent (the events oracle draws
  the identical u01 for the identical key).

Links are named by topology coordinates: a ToR↔spine link ``(tor,
spine)`` covers BOTH directions (the ``tor_up`` and ``spine_down``
queue rows), a host link ``host`` covers the NIC uplink and the
``host_down`` row.  ECMP/spray candidate masks follow flaps: while
``(tor, spine)`` is down the spine leaves ``tor``'s uplink candidate
set, bit-exactly mirroring the static ``dead_links`` path when the
schedule is inert.

The fabric consumes a :class:`FaultSpec` through
``RunConfig(faults=...)`` / ``FabricConfig.faults``; only
:meth:`FaultSpec.shape_key` enters the program cache key.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .topology import FatTree

__all__ = [
    "FaultSpec", "FaultData", "build_fault_data", "validate_faults",
    "fault_u01", "fault_u01_py", "link_flap", "uplink_flap", "host_flap",
    "link_degrade", "link_corrupt", "host_corrupt",
    "faults_from_dead_links", "NEVER",
]

#: Sentinel window end for permanent faults ("down from t0, forever").
#: ``last_edge`` treats windows ending here as open-ended so the default
#: tick horizon is not stretched to the end of time.
NEVER = 2 ** 30


# --------------------------------------------------------------------------- #
# The spec: hashable tuples in, static shape out
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class FaultSpec:
    """A complete time-varying fault schedule (all times in fabric ticks).

    Every field is a tuple of fixed-arity entries so the spec is hashable
    and its *entry counts* form the static shape signature
    (:attr:`shape_key`); the values themselves ride into the compiled
    program as traced arrays (:func:`build_fault_data`).

    * ``link_flaps``:   ``(tor, spine, t0, t1)`` — link down in [t0, t1)
      (BOTH directions: the ``tor_up`` and ``spine_down`` rows blackhole)
    * ``uplink_flaps``: ``(tor, spine, t0, t1)`` — only the ``tor_up``
      direction dies and leaves the ECMP candidate set; the down
      direction keeps serving.  This is exactly the repo's static
      ``dead_links`` semantics made time-varying —
      :func:`faults_from_dead_links` emits these so the degenerate t=0
      schedule is bit-exact against a natively-failed topology.
    * ``host_flaps``:   ``(host, t0, t1)`` — host↔ToR link down in [t0, t1)
    * ``link_degrade``: ``(tor, spine, t0, t1, credit)`` — fractional
      service credit in (0, 1] while the window is active
    * ``link_corrupt``: ``(tor, spine, t0, t1, prob)`` — per-packet drop
      probability in [0, 1] while active
    * ``host_corrupt``: ``(host, t0, t1, prob)`` — same, on the
      host-down (last-hop) link
    * ``seed``: corruption PRNG seed (program data, not shape)
    """

    link_flaps: Tuple[Tuple[int, int, int, int], ...] = ()
    uplink_flaps: Tuple[Tuple[int, int, int, int], ...] = ()
    host_flaps: Tuple[Tuple[int, int, int], ...] = ()
    link_degrade: Tuple[Tuple[int, int, int, int, float], ...] = ()
    link_corrupt: Tuple[Tuple[int, int, int, int, float], ...] = ()
    host_corrupt: Tuple[Tuple[int, int, int, float], ...] = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "link_flaps",
                           tuple(tuple(int(v) for v in e)
                                 for e in self.link_flaps))
        object.__setattr__(self, "uplink_flaps",
                           tuple(tuple(int(v) for v in e)
                                 for e in self.uplink_flaps))
        object.__setattr__(self, "host_flaps",
                           tuple(tuple(int(v) for v in e)
                                 for e in self.host_flaps))
        object.__setattr__(
            self, "link_degrade",
            tuple((int(t), int(s), int(a), int(b), float(c))
                  for (t, s, a, b, c) in self.link_degrade))
        object.__setattr__(
            self, "link_corrupt",
            tuple((int(t), int(s), int(a), int(b), float(p))
                  for (t, s, a, b, p) in self.link_corrupt))
        object.__setattr__(
            self, "host_corrupt",
            tuple((int(h), int(a), int(b), float(p))
                  for (h, a, b, p) in self.host_corrupt))

    # -- static shape --------------------------------------------------- #

    @property
    def seed32(self) -> int:
        """The seed as both backends key it (31 bits: jnp carries it as a
        non-negative i32 scalar; the host mirror masks to match)."""
        return self.seed & 0x7FFFFFFF

    @property
    def shape_key(self) -> tuple:
        """Entry counts only — what the program cache key sees."""
        return (len(self.link_flaps), len(self.uplink_flaps),
                len(self.host_flaps), len(self.link_degrade),
                len(self.link_corrupt), len(self.host_corrupt))

    @property
    def total_entries(self) -> int:
        return sum(self.shape_key)

    @property
    def n_flap_windows(self) -> int:
        """Windows that get per-window retransmit attribution (order:
        link_flaps, then uplink_flaps, then host_flaps)."""
        return (len(self.link_flaps) + len(self.uplink_flaps)
                + len(self.host_flaps))

    @property
    def last_edge(self) -> int:
        """Latest schedule boundary (0 when the spec is empty) — used to
        extend the default tick horizon so recovery has room to drain.
        Windows ending at/after :data:`NEVER` (permanent faults, e.g.
        :func:`faults_from_dead_links`) count their *start* instead: the
        horizon must reach the transition, not the end of time."""
        def _end(t0, t1):
            return t0 if t1 >= NEVER else t1
        edges = [0]
        edges += [_end(a, b) for (_t, _s, a, b) in self.link_flaps]
        edges += [_end(a, b) for (_t, _s, a, b) in self.uplink_flaps]
        edges += [_end(a, b) for (_h, a, b) in self.host_flaps]
        edges += [_end(a, b) for (_t, _s, a, b, _c) in self.link_degrade]
        edges += [_end(a, b) for (_t, _s, a, b, _p) in self.link_corrupt]
        edges += [_end(a, b) for (_h, a, b, _p) in self.host_corrupt]
        return max(edges)


# convenience single-entry constructors ------------------------------------- #

def link_flap(tor: int, spine: int, t0: int, t1: int, **kw) -> FaultSpec:
    return FaultSpec(link_flaps=((tor, spine, t0, t1),), **kw)


def uplink_flap(tor: int, spine: int, t0: int, t1: int, **kw) -> FaultSpec:
    return FaultSpec(uplink_flaps=((tor, spine, t0, t1),), **kw)


def host_flap(host: int, t0: int, t1: int, **kw) -> FaultSpec:
    return FaultSpec(host_flaps=((host, t0, t1),), **kw)


def link_degrade(tor: int, spine: int, t0: int, t1: int,
                 credit: float, **kw) -> FaultSpec:
    return FaultSpec(link_degrade=((tor, spine, t0, t1, credit),), **kw)


def link_corrupt(tor: int, spine: int, t0: int, t1: int,
                 prob: float, seed: int = 0, **kw) -> FaultSpec:
    return FaultSpec(link_corrupt=((tor, spine, t0, t1, prob),),
                     seed=seed, **kw)


def host_corrupt(host: int, t0: int, t1: int, prob: float,
                 seed: int = 0, **kw) -> FaultSpec:
    return FaultSpec(host_corrupt=((host, t0, t1, prob),), seed=seed, **kw)


def faults_from_dead_links(topo: FatTree, t1: int = NEVER) -> FaultSpec:
    """The degenerate t=0 schedule: every static ``dead_links`` entry
    becomes a flap down from tick 0 that never recovers.  (Benchmarks use
    it to express the paper's static link-failure matrix through the
    time-varying subsystem; note the fabric still honours ``dead_links``
    natively, so this is for apples-to-apples chaos-path runs on a
    fully-alive topology.)"""
    return FaultSpec(uplink_flaps=tuple(
        (t, s, 0, t1) for (t, s) in sorted(topo.dead_links)))


# --------------------------------------------------------------------------- #
# Validation (host-side, at run entry)
# --------------------------------------------------------------------------- #

def validate_faults(spec: FaultSpec, topo: FatTree) -> None:
    """Range/sanity checks + the no-total-partition rule: at no tick may a
    ToR lose its last live uplink (static dead links + simultaneous flaps),
    because a fully-disconnected ToR can never drain."""
    T, S, NH = topo.n_tor, topo.n_spine, topo.n_hosts

    def _ck_link(tor, spine, what):
        if not (0 <= tor < T and 0 <= spine < S):
            raise ValueError(f"{what}: link ({tor},{spine}) out of range "
                             f"for {T} ToRs x {S} spines")

    def _ck_win(t0, t1, what):
        # an EMPTY window (t0 == t1) is legal: it is the inert entry chaos
        # soaks use to run clean epochs through the same compiled program
        if not (0 <= t0 <= t1):
            raise ValueError(f"{what}: window [{t0},{t1}) is negative")

    for (t, s, a, b) in spec.link_flaps:
        _ck_link(t, s, "link_flap"); _ck_win(a, b, "link_flap")
        if (t, s) in topo.dead_links:
            raise ValueError(f"link_flap ({t},{s}): link is already in "
                             f"topo.dead_links")
    for (t, s, a, b) in spec.uplink_flaps:
        _ck_link(t, s, "uplink_flap"); _ck_win(a, b, "uplink_flap")
        if (t, s) in topo.dead_links:
            raise ValueError(f"uplink_flap ({t},{s}): link is already in "
                             f"topo.dead_links")
    for (h, a, b) in spec.host_flaps:
        if not 0 <= h < NH:
            raise ValueError(f"host_flap: host {h} out of range")
        _ck_win(a, b, "host_flap")
    for (t, s, a, b, c) in spec.link_degrade:
        _ck_link(t, s, "link_degrade"); _ck_win(a, b, "link_degrade")
        if not 0.0 < c <= 1.0:
            raise ValueError(f"link_degrade credit {c} not in (0, 1]")
    for (t, s, a, b, p) in spec.link_corrupt:
        _ck_link(t, s, "link_corrupt"); _ck_win(a, b, "link_corrupt")
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"link_corrupt prob {p} not in [0, 1]")
    for (h, a, b, p) in spec.host_corrupt:
        if not 0 <= h < NH:
            raise ValueError(f"host_corrupt: host {h} out of range")
        _ck_win(int(a), int(b), "host_corrupt")
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"host_corrupt prob {p} not in [0, 1]")
    # no ToR may lose every uplink at once: sweep the flap boundary set
    all_flaps = spec.link_flaps + spec.uplink_flaps
    if all_flaps:
        edges = sorted({e for (_, _, a, b) in all_flaps
                        for e in (a, b)})
        for t in range(T):
            live = set(topo.live_up[t])
            flaps = [(s, a, b) for (tt, s, a, b) in all_flaps
                     if tt == t]
            for e in edges:
                down = {s for (s, a, b) in flaps if a <= e < b}
                if live and not (live - down):
                    raise ValueError(
                        f"link_flaps fully disconnect ToR {t} at tick {e};"
                        f" a partitioned ToR can never drain")


# --------------------------------------------------------------------------- #
# FaultData: the traced program argument (queue-row resolved)
# --------------------------------------------------------------------------- #

class FaultData(NamedTuple):
    """Schedule arrays as the fabric consumes them.  Shapes depend only on
    ``FaultSpec.shape_key``; the queue-row resolution matches fabric.py's
    layout (tor_up ``t*S+s`` | spine_down ``TS+s*T+t`` | host_down
    ``2*TS+h``)."""

    seed: jax.Array        # i32[] corruption PRNG seed
    flap_row: jax.Array    # i32[FR] queue rows down in [t0, t1)
    flap_row_t0: jax.Array
    flap_row_t1: jax.Array
    flap_nic: jax.Array    # i32[FH] hosts whose NIC uplink is down
    flap_nic_t0: jax.Array
    flap_nic_t1: jax.Array
    flap_up: jax.Array     # i32[FL] flat t*S+s uplinks out of ECMP while down
    flap_up_t0: jax.Array
    flap_up_t1: jax.Array
    deg_row: jax.Array     # i32[DR] degraded rows
    deg_t0: jax.Array
    deg_t1: jax.Array
    deg_num: jax.Array     # i32[DR] credit numerator out of 256
    cor_row: jax.Array     # i32[CR] corrupting rows
    cor_t0: jax.Array
    cor_t1: jax.Array
    cor_p: jax.Array       # f32[CR]
    edges: jax.Array       # i32[E] every t0/t1 (warp wake sources)
    win_t0: jax.Array      # i32[W] flap windows (retx attribution)
    win_t1: jax.Array


def _i32(xs) -> jnp.ndarray:
    return jnp.asarray(np.asarray(xs, dtype=np.int32))


def build_fault_data(spec: Optional[FaultSpec], n_tor: int, n_spine: int,
                     hosts_per_tor: int) -> FaultData:
    """Expand a spec to queue-row-resolved arrays (empty spec -> zero-length
    arrays; the program signature is identical either way)."""
    spec = spec or FaultSpec()
    T, S = n_tor, n_spine
    TS = T * S
    rows, r0, r1 = [], [], []
    ups, u0, u1 = [], [], []
    for (t, s, a, b) in spec.link_flaps:
        rows += [t * S + s, TS + s * T + t]     # both directions die
        r0 += [a, a]; r1 += [b, b]
        ups.append(t * S + s); u0.append(a); u1.append(b)
    for (t, s, a, b) in spec.uplink_flaps:
        rows.append(t * S + s)                  # up direction only
        r0.append(a); r1.append(b)
        ups.append(t * S + s); u0.append(a); u1.append(b)
    nics, n0, n1 = [], [], []
    for (h, a, b) in spec.host_flaps:
        rows.append(2 * TS + h); r0.append(a); r1.append(b)
        nics.append(h); n0.append(a); n1.append(b)
    dr, d0, d1, dn = [], [], [], []
    for (t, s, a, b, c) in spec.link_degrade:
        num = max(1, min(256, int(round(c * 256))))
        dr += [t * S + s, TS + s * T + t]
        d0 += [a, a]; d1 += [b, b]; dn += [num, num]
    cr, c0, c1, cp = [], [], [], []
    for (t, s, a, b, p) in spec.link_corrupt:
        cr += [t * S + s, TS + s * T + t]
        c0 += [a, a]; c1 += [b, b]; cp += [p, p]
    for (h, a, b, p) in spec.host_corrupt:
        cr.append(2 * TS + h); c0.append(int(a)); c1.append(int(b))
        cp.append(p)
    # NOT deduplicated: the edge-array length must follow from shape_key
    # alone (dedup would make the traced shape value-dependent and break
    # one-compile chaos epochs); duplicate wake sources are harmless mins
    edges = r0 + r1 + d0 + d1 + c0 + c1
    wt0 = [a for (_, _, a, _) in spec.link_flaps] \
        + [a for (_, _, a, _) in spec.uplink_flaps] \
        + [a for (_, a, _) in spec.host_flaps]
    wt1 = [b for (_, _, _, b) in spec.link_flaps] \
        + [b for (_, _, _, b) in spec.uplink_flaps] \
        + [b for (_, _, b) in spec.host_flaps]
    return FaultData(
        seed=jnp.int32(spec.seed32),
        flap_row=_i32(rows), flap_row_t0=_i32(r0), flap_row_t1=_i32(r1),
        flap_nic=_i32(nics), flap_nic_t0=_i32(n0), flap_nic_t1=_i32(n1),
        flap_up=_i32(ups), flap_up_t0=_i32(u0), flap_up_t1=_i32(u1),
        deg_row=_i32(dr), deg_t0=_i32(d0), deg_t1=_i32(d1),
        deg_num=_i32(dn),
        cor_row=_i32(cr), cor_t0=_i32(c0), cor_t1=_i32(c1),
        cor_p=jnp.asarray(np.asarray(cp, dtype=np.float32)),
        edges=_i32(edges), win_t0=_i32(wt0), win_t1=_i32(wt1))


def duty_open(t: jax.Array, num: jax.Array) -> jax.Array:
    """True on ticks where a ``num/256`` duty cycle grants a service slot
    (deterministic, stateless: the credit integral crosses an integer)."""
    return ((t + 1) * num) // 256 > (t * num) // 256


def duty_open_py(t: int, num: int) -> bool:
    return ((t + 1) * num) // 256 > (t * num) // 256


# --------------------------------------------------------------------------- #
# Counter-based splitmix64 on 2x uint32 limbs (f32-safe, no x64 needed)
# --------------------------------------------------------------------------- #

_GOLDEN = (0x9E3779B9, 0x7F4A7C15)
_C1 = (0xBF58476D, 0x1CE4E5B9)
_C2 = (0x94D049BB, 0x133111EB)


def _mul64(ah, al, bh, bl):
    """(ah<<32|al) * (bh<<32|bl) mod 2^64, on uint32 limbs (16-bit
    partial products keep every intermediate inside uint32)."""
    mask16 = jnp.uint32(0xFFFF)
    a0, a1 = al & mask16, al >> 16
    b0, b1 = bl & mask16, bl >> 16
    p00 = a0 * b0
    p01 = a0 * b1
    p10 = a1 * b0
    mid = (p00 >> 16) + (p01 & mask16) + (p10 & mask16)
    lo = (p00 & mask16) | ((mid & mask16) << 16)
    hi = (mid >> 16) + (p01 >> 16) + (p10 >> 16) + a1 * b1 \
        + al * bh + ah * bl                      # uint32 wraps = mod 2^32
    return hi, lo


def _add64(ah, al, bh, bl):
    lo = al + bl
    carry = (lo < al).astype(jnp.uint32)
    return ah + bh + carry, lo


def _xorshift64(h, l, k: int):
    """x ^= x >> k for 0 < k < 32 (the splitmix64 shifts: 30, 27, 31)."""
    sh = h >> k
    sl = (l >> k) | (h << (32 - k))
    return h ^ sh, l ^ sl


def _splitmix_state(h, l):
    """One splitmix64 output step from state (h, l) — already advanced."""
    zh, zl = _xorshift64(h, l, 30)
    zh, zl = _mul64(zh, zl, jnp.uint32(_C1[0]), jnp.uint32(_C1[1]))
    zh, zl = _xorshift64(zh, zl, 27)
    zh, zl = _mul64(zh, zl, jnp.uint32(_C2[0]), jnp.uint32(_C2[1]))
    return _xorshift64(zh, zl, 31)


def _splitmix64_jnp(h, l):
    h, l = _add64(h, l, jnp.uint32(_GOLDEN[0]), jnp.uint32(_GOLDEN[1]))
    return (h, l), _splitmix_state(h, l)


def _u64_jnp(seed, *counters):
    """jnp mirror of ``traffic._u64``: seed is an i32 scalar, counters are
    non-negative i32 arrays/scalars; returns the output as uint32 limbs."""
    sh = jnp.uint32(0)
    sl = seed.astype(jnp.uint32) if hasattr(seed, "astype") \
        else jnp.uint32(seed)
    (sh, sl), (oh, ol) = _splitmix64_jnp(sh, sl)
    for c in counters:
        ch = jnp.uint32(0)
        cl = jnp.asarray(c).astype(jnp.uint32)
        ch, cl = _mul64(ch, cl, jnp.uint32(_GOLDEN[0]),
                        jnp.uint32(_GOLDEN[1]))
        sh, sl = oh ^ ch, ol ^ cl
        (sh, sl), (oh, ol) = _splitmix64_jnp(sh, sl)
    return oh, ol


def fault_u01(seed, *counters) -> jax.Array:
    """f32 in [0, 1) from the top 24 bits of the keyed splitmix64 stream —
    exactly representable in f32, so every jnp backend draws the same
    value; :func:`fault_u01_py` is the bit-identical host mirror."""
    oh, _ = _u64_jnp(seed, *counters)
    return (oh >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def fault_u01_py(seed: int, *counters: int) -> float:
    """Host mirror of :func:`fault_u01` (the events oracle's draw)."""
    from .traffic import _u64  # function-level: traffic imports workloads
    return float(_u64(seed, *counters) >> 40) * (1.0 / (1 << 24))
