"""RoCEv2 on the fast path — DCQCN + go-back-N as fixed-shape JAX transitions.

The jnp mirror of the event oracle's RoCEv2 engines (``core/ref.py``:
``RoCESender`` / ``RoCEReceiver`` / ``DCQCNState``), shaped so the jitted
fabric can ``vmap`` them across flows exactly like the STrack engines in
``core/transport.py``:

  * **DCQCN** (Zhu et al., SIGCOMM'15): rate-based CC — the receiver turns
    ECN marks into CNPs (at most one per ``cnp_interval_us`` per flow), the
    sender cuts ``rate *= 1 - alpha/2`` per CNP, ewma's alpha, and recovers
    through fast-recovery / additive-increase / hyper-increase stages driven
    by the byte counter and the rate timer.  Constants come from
    ``core.params.make_dcqcn_params``.
  * **Go-back-N**: the receiver only accepts in-order PSNs; a gap produces a
    NACK carrying the expected PSN and the sender rewinds ``psn_next`` to it,
    retransmitting the whole tail.  An RTO rewind covers tail drops.
  * Single path: each flow carries one fixed entropy value (one QP), as in
    the paper's un-striped RoCEv2 baseline.

Everything here is a pure function over :class:`RoceFlow` / :class:`RoceRcv`
NamedTuples; ``fabric.make_rocev2_protocol`` packages them into the
fabric's :class:`~repro.sim.fabric.Protocol` dispatch record.  The PFC pause
model itself lives in the fabric's queue layer (it is a switch property,
not a flow property).  Times in us, sizes in bytes, rates in bytes/us.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.params import DCQCNParams, NetworkSpec, RoCEParams


@dataclasses.dataclass(frozen=True)
class RoceFabParams:
    """Scalars the vmapped RoCEv2 transitions close over."""

    dcqcn: DCQCNParams
    mtu_bytes: int
    line_rate_Bpus: float
    window_pkts: float         # static send window (lossless net): ~1 BDP
    rto_us: float
    ack_coalesce_pkts: int
    cnp_interval_us: float
    tick_us: float             # pacing comparisons tolerate half a tick


def make_roce_fab_params(net: NetworkSpec, rp: RoCEParams) -> RoceFabParams:
    return RoceFabParams(
        dcqcn=rp.dcqcn,
        mtu_bytes=net.mtu_bytes,
        line_rate_Bpus=net.rate_Bpus,
        window_pkts=net.bdp_pkts,
        rto_us=rp.rto_us,
        ack_coalesce_pkts=rp.ack_coalesce_pkts,
        cnp_interval_us=rp.dcqcn.cnp_interval_us,
        tick_us=net.mtu_serialize_us,
    )


class RoceFlow(NamedTuple):
    """Sender state: go-back-N window + DCQCN rate machine."""

    snd_una: jax.Array        # i32: cumulative ack point
    psn_next: jax.Array       # i32
    total_pkts: jax.Array     # i32
    rate: jax.Array           # f32, bytes/us (current sending rate)
    target: jax.Array         # f32, bytes/us (fast-recovery target)
    alpha: jax.Array          # f32: ECN ewma
    t_stage: jax.Array        # i32: rate-timer stages since last CNP
    b_stage: jax.Array        # i32: byte-counter stages since last CNP
    bytes_ctr: jax.Array      # f32
    last_rate_ts: jax.Array   # f32
    last_alpha_ts: jax.Array  # f32
    next_send_ts: jax.Array   # f32: pacing gate
    rto_deadline: jax.Array   # f32
    entropy: jax.Array        # i32: fixed path (one QP)
    retransmits: jax.Array    # i32
    tail_bytes: jax.Array     # f32: wire size of the final PSN (odd tail)
    max_psn: jax.Array        # i32: highest PSN ever sent + 1 (rtx detect)
    rto_fires: jax.Array      # i32: RTO expirations
    gbn_rewinds: jax.Array    # i32: NACK-triggered go-back-N rewinds


class RoceRcv(NamedTuple):
    """In-order-only receiver: cumulative ACKs, NACKs on gaps, CNPs on ECN."""

    epsn: jax.Array           # i32
    total_pkts: jax.Array     # i32
    since_ack: jax.Array      # i32: packets since last cumulative ack
    last_cnp_ts: jax.Array    # f32
    bytes_recvd: jax.Array    # f32


class RoceMsg(NamedTuple):
    """Return-pipe wire format (the RoCE analogue of ``SackMsg``).

    One delivered data packet can produce a CNP *and* an ACK/NACK in the
    oracle; here they ride the same pipe slot and ``roce_on_ack`` applies
    both effects.
    """

    valid: jax.Array          # bool: any of ack/nack/cnp present
    ack: jax.Array            # bool
    nack: jax.Array           # bool
    cnp: jax.Array            # bool
    epsn: jax.Array           # i32 (for ack/nack)
    bytes_recvd: jax.Array    # f32


def init_roce_flow(p: RoceFabParams, total_pkts, entropy,
                   now: float = 0.0, tail_bytes=None) -> RoceFlow:
    f = lambda v: jnp.full((), v, jnp.float32)
    i = lambda v: jnp.asarray(v, jnp.int32)
    if tail_bytes is None:
        tail_bytes = float(p.mtu_bytes)
    return RoceFlow(
        snd_una=i(0), psn_next=i(0), total_pkts=i(total_pkts),
        rate=f(p.line_rate_Bpus), target=f(p.line_rate_Bpus),
        alpha=f(1.0), t_stage=i(0), b_stage=i(0), bytes_ctr=f(0.0),
        last_rate_ts=f(now), last_alpha_ts=f(now), next_send_ts=f(now),
        rto_deadline=f(now + p.rto_us), entropy=i(entropy),
        retransmits=i(0), tail_bytes=jnp.asarray(tail_bytes, jnp.float32),
        max_psn=i(0), rto_fires=i(0), gbn_rewinds=i(0))


def init_roce_rcv(total_pkts) -> RoceRcv:
    return RoceRcv(epsn=jnp.zeros((), jnp.int32),
                   total_pkts=jnp.asarray(total_pkts, jnp.int32),
                   since_ack=jnp.zeros((), jnp.int32),
                   last_cnp_ts=jnp.full((), -1e18, jnp.float32),
                   bytes_recvd=jnp.zeros((), jnp.float32))


def empty_roce_msgs(h: int, n: int) -> RoceMsg:
    z = lambda dt: jnp.zeros((h, n), dt)
    return RoceMsg(valid=z(bool), ack=z(bool), nack=z(bool), cnp=z(bool),
                   epsn=z(jnp.int32), bytes_recvd=z(jnp.float32))


def roce_done(fs: RoceFlow) -> jax.Array:
    return fs.snd_una >= fs.total_pkts


def _increase(p: DCQCNParams, rate, target, t_stage, b_stage, max_rate):
    """DCQCN phase step: hyper when BOTH counters passed F, additive when
    EITHER did, else fast recovery (rate -> (rate+target)/2)."""
    hyper = jnp.minimum(t_stage, b_stage) > p.f_fast_recovery
    addi = jnp.maximum(t_stage, b_stage) > p.f_fast_recovery
    target = jnp.where(hyper, jnp.minimum(target + p.hai_mbps, max_rate),
                       jnp.where(addi,
                                 jnp.minimum(target + p.rai_mbps, max_rate),
                                 target))
    rate = jnp.minimum((rate + target) / 2.0, max_rate)
    return rate, target


def roce_next_packet(fs: RoceFlow, p: RoceFabParams, now: jax.Array):
    """on_sending_packet: window + pacing gate, byte-counter stage update.

    Returns (new_state, (valid, psn, entropy, is_rtx)). The caller only
    commits ``new_state`` for the flow its NIC actually selected this tick.
    """
    now = jnp.asarray(now, jnp.float32)
    dc = p.dcqcn
    done = roce_done(fs)
    # half-a-tick pacing tolerance: f32 `now` accumulates rounding error and
    # an exact >= comparison would skip ticks at line rate
    can = (~done) & (fs.psn_next < fs.total_pkts) \
        & (now + 0.5 * p.tick_us >= fs.next_send_ts) \
        & ((fs.psn_next - fs.snd_una).astype(jnp.float32) < p.window_pkts)
    psn = fs.psn_next
    # a PSN below the high-water mark is a go-back-N resend (rewinds pull
    # psn_next back below max_psn; impossible without loss)
    is_rtx = can & (psn < fs.max_psn)
    # full MTU except the message's odd tail packet (ref.pkt_size)
    size = jnp.where(psn >= fs.total_pkts - 1, fs.tail_bytes,
                     jnp.float32(p.mtu_bytes))

    # DCQCN byte counter (oracle: on_bytes_sent before pacing the next send)
    bytes_ctr = fs.bytes_ctr + size
    b_hit = bytes_ctr >= dc.byte_counter
    b_stage = fs.b_stage + b_hit.astype(jnp.int32)
    inc_rate, inc_target = _increase(dc, fs.rate, fs.target, fs.t_stage,
                                     b_stage, p.line_rate_Bpus)
    rate = jnp.where(b_hit, inc_rate, fs.rate)
    target = jnp.where(b_hit, inc_target, fs.target)
    bytes_ctr = jnp.where(b_hit, 0.0, bytes_ctr)

    next_send_ts = now + size / jnp.maximum(rate, 1e-9)
    new = fs._replace(
        psn_next=psn + 1,
        max_psn=jnp.maximum(fs.max_psn, psn + 1),
        rate=rate, target=target,
        b_stage=b_stage, bytes_ctr=bytes_ctr,
        next_send_ts=next_send_ts)
    new = jax.tree.map(lambda n_, o: jnp.where(can, n_, o), new, fs)
    return new, (can, psn, fs.entropy, is_rtx)


def roce_on_ack(fs: RoceFlow, p: RoceFabParams, msg: RoceMsg,
                now: jax.Array) -> RoceFlow:
    """Apply one return-pipe message: CNP rate cut, then ACK/NACK."""
    now = jnp.asarray(now, jnp.float32)
    dc = p.dcqcn

    # --- CNP: multiplicative cut + alpha ewma + stage reset ---
    cnp = msg.valid & msg.cnp
    rate = jnp.where(cnp,
                     jnp.maximum(fs.rate * (1 - fs.alpha / 2),
                                 dc.min_rate_Bpus), fs.rate)
    target = jnp.where(cnp, fs.rate, fs.target)
    alpha = jnp.where(cnp, (1 - dc.g) * fs.alpha + dc.g, fs.alpha)
    t_stage = jnp.where(cnp, 0, fs.t_stage)
    b_stage = jnp.where(cnp, 0, fs.b_stage)
    bytes_ctr = jnp.where(cnp, 0.0, fs.bytes_ctr)
    last_rate_ts = jnp.where(cnp, now, fs.last_rate_ts)
    last_alpha_ts = jnp.where(cnp, now, fs.last_alpha_ts)

    # --- cumulative ack / go-back-N rewind ---
    acked = msg.valid & (msg.ack | msg.nack)
    adv = acked & (msg.epsn > fs.snd_una)
    snd_una = jnp.where(adv, msg.epsn, fs.snd_una)
    nack = msg.valid & msg.nack
    rewind_to = jnp.maximum(snd_una, msg.epsn)
    retransmits = fs.retransmits + jnp.where(
        nack, jnp.maximum(fs.psn_next - msg.epsn, 0), 0)
    gbn_rewinds = fs.gbn_rewinds + (
        nack & (fs.psn_next > rewind_to)).astype(jnp.int32)
    psn_next = jnp.where(nack, rewind_to, fs.psn_next)
    rto_deadline = jnp.where(adv | nack, now + p.rto_us, fs.rto_deadline)

    return fs._replace(
        snd_una=snd_una, psn_next=psn_next,
        rate=rate, target=target, alpha=alpha,
        t_stage=t_stage, b_stage=b_stage, bytes_ctr=bytes_ctr,
        last_rate_ts=last_rate_ts, last_alpha_ts=last_alpha_ts,
        rto_deadline=rto_deadline, retransmits=retransmits,
        gbn_rewinds=gbn_rewinds)


def roce_on_timer(fs: RoceFlow, p: RoceFabParams, now: jax.Array):
    """Alpha-decay + rate-increase timers, RTO go-back-N rewind.

    Returns (new_state, emit_probe) — RoCEv2 sends no probes, so the probe
    flag is always False (the fabric's TxPacket slot stays empty).
    """
    now = jnp.asarray(now, jnp.float32)
    dc = p.dcqcn
    active = ~roce_done(fs)

    alpha_due = active & (now - fs.last_alpha_ts >= dc.alpha_timer_us)
    alpha = jnp.where(alpha_due, (1 - dc.g) * fs.alpha, fs.alpha)
    last_alpha_ts = jnp.where(alpha_due, now, fs.last_alpha_ts)

    rate_due = active & (now - fs.last_rate_ts >= dc.rate_timer_us)
    t_stage = fs.t_stage + rate_due.astype(jnp.int32)
    inc_rate, inc_target = _increase(dc, fs.rate, fs.target, t_stage,
                                     fs.b_stage, p.line_rate_Bpus)
    rate = jnp.where(rate_due, inc_rate, fs.rate)
    target = jnp.where(rate_due, inc_target, fs.target)
    last_rate_ts = jnp.where(rate_due, now, fs.last_rate_ts)

    rto = active & (now >= fs.rto_deadline)
    psn_next = jnp.where(rto, fs.snd_una, fs.psn_next)
    rto_deadline = jnp.where(rto, now + p.rto_us, fs.rto_deadline)
    # a rewind re-sends [snd_una, psn_next): attribute those to retransmits
    # the same way the NACK path does
    retransmits = fs.retransmits + jnp.where(
        rto, jnp.maximum(fs.psn_next - fs.snd_una, 0), 0)

    return fs._replace(
        alpha=alpha, last_alpha_ts=last_alpha_ts,
        rate=rate, target=target, t_stage=t_stage,
        last_rate_ts=last_rate_ts,
        psn_next=psn_next, rto_deadline=rto_deadline,
        retransmits=retransmits,
        rto_fires=fs.rto_fires + rto.astype(jnp.int32)), jnp.zeros((), bool)


def roce_next_event(fs: RoceFlow, p: RoceFabParams,
                    ) -> tuple[jax.Array, jax.Array]:
    """(next timer event time, next pacing release time) for the
    event-horizon scan in ``sim/fabric.py``.

    ``roce_on_timer`` is a no-op before the earliest of the RTO deadline
    and the alpha/rate DCQCN timers; ``roce_next_packet`` cannot fire
    before the pacing gate ``next_send_ts`` opens (and never, if the
    go-back-N window is closed — then only a timer can wake the flow).
    """
    dc = p.dcqcn
    inf = jnp.float32(jnp.inf)
    active = ~roce_done(fs)
    timer_ev = jnp.minimum(
        fs.rto_deadline,
        jnp.minimum(fs.last_alpha_ts + dc.alpha_timer_us,
                    fs.last_rate_ts + dc.rate_timer_us))
    window_open = (fs.psn_next < fs.total_pkts) \
        & ((fs.psn_next - fs.snd_una).astype(jnp.float32) < p.window_pkts)
    return (jnp.where(active, timer_ev, inf),
            jnp.where(active & window_open, fs.next_send_ts, inf))


def roce_on_data(rs: RoceRcv, p: RoceFabParams, psn: jax.Array,
                 size: jax.Array, ecn: jax.Array, now: jax.Array,
                 ) -> tuple[RoceRcv, RoceMsg]:
    """Receiver: cumulative ack (coalesced), NACK on gap, paced CNP on ECN."""
    now = jnp.asarray(now, jnp.float32)
    psn = jnp.asarray(psn, jnp.int32)

    cnp = jnp.asarray(ecn, bool) & (now - rs.last_cnp_ts >= p.cnp_interval_us)
    last_cnp_ts = jnp.where(cnp, now, rs.last_cnp_ts)

    inorder = psn == rs.epsn
    dup = psn < rs.epsn
    ooo = psn > rs.epsn

    epsn = jnp.where(inorder, rs.epsn + 1, rs.epsn)
    bytes_recvd = rs.bytes_recvd + jnp.where(
        inorder, jnp.asarray(size, jnp.float32), 0.0)
    since_ack = rs.since_ack + inorder.astype(jnp.int32)
    ack = (inorder & ((since_ack >= p.ack_coalesce_pkts)
                      | (epsn >= rs.total_pkts))) | dup
    since_ack = jnp.where(inorder & ack, 0, since_ack)

    msg = RoceMsg(valid=ack | ooo | cnp, ack=ack, nack=ooo, cnp=cnp,
                  epsn=epsn, bytes_recvd=bytes_recvd)
    return RoceRcv(epsn=epsn, total_pkts=rs.total_pkts, since_ack=since_ack,
                   last_cnp_ts=last_cnp_ts, bytes_recvd=bytes_recvd), msg
