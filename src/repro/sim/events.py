"""Event-driven packet-level network simulator (the semantics oracle).

htsim-style discrete-event simulation of the paper's evaluation fabric:

* directional FIFO queues with serialization + propagation delay,
* egress ECN marking (mark on dequeue from the residual queue depth —
  the paper's "egress-marked ECN" early signal),
* silent tail drops at ``drop_bytes`` (STrack mode, lossy),
* PFC with per-ingress accounting and dynamic-threshold shared buffer
  (RoCEv2 mode, lossless),
* pull-based host NICs (ACK-clocked window transports ask the flow engine
  for the next packet only when the wire is free),
* pluggable workloads (permutation / incast / collective traces) via a
  message-completion callback.

Transports plug in through the engines in ``repro.core.ref`` (STrack) and
the RoCEv2/DCQCN baseline.  Times in us, sizes in bytes.

This module is the *semantics oracle*: both protocols, dependency-
scheduled collective traces (figs 21-28) and 4-QP sub-flow striping all
also run on the jitted multi-queue fabric (``fabric.py`` +
``dcqcn_fab.py``, ~1000x faster), which is parity-tested against this
implementation in ``tests/test_fabric.py`` (STrack),
``tests/test_fabric_roce.py`` (RoCEv2/PFC) and
``tests/test_collective_fabric.py`` (collectives, via
``workloads.TraceRunner`` on this engine).  See the sim/ module map in
``fabric.py``; the public entry point is ``workloads.run(scenario, cfg)``.
"""
from __future__ import annotations

import heapq
import itertools
import math
from typing import Callable, Optional

from ..core import ref
from ..core.params import (NetworkSpec, RoCEParams, STrackParams,
                           make_roce_params, make_strack_params)
from .faults import FaultSpec, fault_u01_py, validate_faults
from .topology import FatTree, _mix

#: Legacy default per-link propagation delay (us).  Since the per-hop
#: latency model landed, NetSim derives its propagation from
#: ``NetworkSpec.hop_prop_effective_us`` — the same knob the jitted fabric
#: uses — so an uncongested cross-ToR data+ACK round trip realizes exactly
#: ``net.base_rtt_us`` on BOTH backends.  This constant remains only as
#: the historical reference value.
PROP_DELAY_US = 0.5


class Queue:
    """Directional FIFO with serialization, ECN egress marking, drops, PFC."""

    __slots__ = ("name", "rate", "prop", "fifo", "occ", "busy", "paused",
                 "ecn_kmin", "ecn_kmax", "drop_bytes", "switch",
                 "drops", "max_occ", "delay_log", "sim", "drain_host",
                 "flap_wins", "degrade", "cor_wins", "fault_row")

    def __init__(self, sim, name, rate, prop, ecn_kmin=None, ecn_kmax=None,
                 drop_bytes=None, switch=None, drain_host=None):
        self.sim = sim
        self.name = name
        self.rate = rate            # bytes/us
        self.prop = prop            # us
        self.fifo: list = []        # list of (pkt, next_hop, enq_ts)
        self.occ = 0.0              # bytes
        self.busy = False
        self.paused = False
        self.ecn_kmin = ecn_kmin
        self.ecn_kmax = ecn_kmax
        self.drop_bytes = drop_bytes
        self.switch = switch        # Switch owning this EGRESS queue (or None)
        self.drain_host = drain_host  # host id to re-pump when NIC drains
        self.drops = 0
        self.max_occ = 0.0
        self.delay_log: Optional[list] = None
        # chaos schedule (sim/faults.py), windows in us:
        self.flap_wins: tuple = ()   # (t0, t1): link down, blackhole
        self.degrade: tuple = ()     # (t0, t1, credit): scaled service rate
        self.cor_wins: tuple = ()    # (t0, t1, p): seeded corruption drop
        self.fault_row = -1          # fabric queue-row id (PRNG keying)

    def enqueue(self, pkt, next_hop, now):
        sim = self.sim
        if self.drop_bytes is not None and pkt.kind == ref.DATA \
                and self.occ + pkt.size > self.drop_bytes:
            self.drops += 1
            sim.total_drops += 1
            return  # silent drop
        self.fifo.append((pkt, next_hop, now))
        self.occ += pkt.size
        if self.occ > self.max_occ:
            self.max_occ = self.occ
        if self.switch is not None:
            self.switch.on_enqueue(pkt, self, now)
        if not self.busy and not self.paused:
            self.busy = True
            sim.schedule(now + pkt.size / self._rate_at(now), "deq", self)

    def _rate_at(self, now):
        """Service rate honouring any active degrade window (fractional
        service credit — the oracle's analogue of the fabric's duty
        gating)."""
        r = self.rate
        for a, b, c in self.degrade:
            if a <= now < b:
                r = self.rate * c
        return r

    def service(self, now):
        """Dequeue-completion event: head packet finished serializing."""
        pkt, next_hop, enq_ts = self.fifo.pop(0)
        self.occ -= pkt.size
        if self.delay_log is not None:
            qdelay = now - enq_ts - pkt.size / self.rate
            if qdelay > self.sim.qdelay_log_threshold:
                self.delay_log.append((now, qdelay))
        # Egress ECN: mark by the RESIDUAL queue (the queue behind this pkt).
        if self.ecn_kmin is not None and pkt.kind == ref.DATA:
            q = self.occ
            if q >= self.ecn_kmax:
                pkt.ecn = True
            elif q > self.ecn_kmin:
                frac = (q - self.ecn_kmin) / max(self.ecn_kmax - self.ecn_kmin, 1e-9)
                if self.sim.rng.random() < frac:
                    pkt.ecn = True
        if self.switch is not None:
            self.switch.on_dequeue(pkt, self, now)
        # Chaos schedule: a down link blackholes everything it serves (the
        # packet really left the buffer — PFC accounting above already ran
        # — it just never arrives); corruption drops DATA only, drawn from
        # the same counter-based PRNG the fabric uses, keyed by
        # (seed, queue-row, serve tick, psn).
        lost = False
        if self.flap_wins and any(a <= now < b for a, b in self.flap_wins):
            self.sim.blackholed_pkts += 1
            lost = True
        elif self.cor_wins and pkt.kind == ref.DATA:
            p = max((p_ for a, b, p_ in self.cor_wins if a <= now < b),
                    default=0.0)
            if p > 0.0:
                tick = int(now / self.sim.net.mtu_serialize_us)
                u = fault_u01_py(self.sim.fault_seed, self.fault_row,
                                 tick, pkt.psn)
                if u < p:
                    self.sim.corrupt_drops += 1
                    lost = True
        if not lost:
            self.sim.schedule(now + self.prop, "hop", (pkt, next_hop))
        if self.fifo and not self.paused:
            self.sim.schedule(now + self.fifo[0][0].size / self._rate_at(now),
                              "deq", self)
        else:
            self.busy = False
            if self.drain_host is not None and not self.fifo:
                # NIC wire is free again: let the host clock out more packets
                self.sim.schedule_pump(now, self.drain_host)

    def pause(self, now):
        self.paused = True

    def resume(self, now):
        if self.paused:
            self.paused = False
            if self.fifo and not self.busy:
                self.busy = True
                self.sim.schedule(now + self.fifo[0][0].size
                                  / self._rate_at(now), "deq", self)


class Switch:
    """Shared-buffer switch with per-ingress-port PFC (RoCEv2 mode)."""

    __slots__ = ("name", "buffer_bytes", "total_occ", "ingress_occ",
                 "upstream", "pfc_enabled", "paused_ports", "pfc_alpha",
                 "pause_events", "sim")

    def __init__(self, sim, name, buffer_bytes, pfc_enabled):
        self.sim = sim
        self.name = name
        self.buffer_bytes = buffer_bytes
        self.total_occ = 0.0
        self.ingress_occ: dict = {}
        self.upstream: dict = {}    # port -> upstream Queue to pause
        self.pfc_enabled = pfc_enabled
        self.paused_ports: set = set()
        self.pfc_alpha = 1.0
        self.pause_events = 0

    def register_ingress(self, port, upstream_queue):
        self.ingress_occ[port] = 0.0
        self.upstream[port] = upstream_queue

    def _xoff(self) -> float:
        # dynamic threshold (DT): alpha * remaining shared buffer
        free = max(self.buffer_bytes - self.total_occ, 0.0)
        return self.pfc_alpha * free / (1.0 + self.pfc_alpha)

    def on_enqueue(self, pkt, queue, now):
        port = getattr(pkt, "_ingress", None)
        if port is None:
            return
        self.total_occ += pkt.size
        self.ingress_occ[port] = self.ingress_occ.get(port, 0.0) + pkt.size
        if not self.pfc_enabled:
            return
        if port not in self.paused_ports \
                and self.ingress_occ[port] > self._xoff():
            self.paused_ports.add(port)
            self.pause_events += 1
            self.sim.pause_log.append(now)
            up = self.upstream.get(port)
            if up is not None:
                self.sim.schedule(now + self.sim.prop_us, "pause", up)

    def on_dequeue(self, pkt, queue, now):
        port = getattr(pkt, "_ingress", None)
        if port is None:
            return
        self.total_occ -= pkt.size
        self.ingress_occ[port] -= pkt.size
        if self.pfc_enabled and port in self.paused_ports \
                and self.ingress_occ[port] < 0.5 * self._xoff():
            self.paused_ports.discard(port)
            up = self.upstream.get(port)
            if up is not None:
                self.sim.schedule(now + self.sim.prop_us, "resume", up)


class Flow:
    """One message between (src, dst). Owns sender+receiver engines."""

    __slots__ = ("id", "src", "dst", "msg_bytes", "sender", "receiver",
                 "start_ts", "timer_seq", "meta", "_parent", "_parts",
                 "_remaining", "_done_ts")

    def __init__(self, fid, src, dst, msg_bytes, start_ts, meta=None):
        self.id = fid
        self.src = src
        self.dst = dst
        self.msg_bytes = msg_bytes
        self.start_ts = start_ts
        self.sender = None
        self.receiver = None
        self.timer_seq = 0
        self.meta = meta

    @property
    def done_ts(self):
        if getattr(self, "_parts", None) is not None:
            return getattr(self, "_done_ts", None)
        return self.sender.done_ts

    @property
    def fct(self):
        dt = self.done_ts
        return dt - self.start_ts if dt is not None else None


class NetSim:
    """The discrete-event engine."""

    def __init__(self, topo: FatTree, net: NetworkSpec, *,
                 transport: str = "strack",
                 strack_params: Optional[STrackParams] = None,
                 roce_params: Optional[RoCEParams] = None,
                 oblivious_spray: bool = False,
                 switch_buffer_bytes: float = 64e6,
                 qdelay_log_threshold: float = 8.0,
                 log_queues: bool = False,
                 faults: Optional[FaultSpec] = None,
                 seed: int = 1234):
        import random
        self.rng = random.Random(seed)
        self.topo = topo
        self.net = net
        self.transport = transport
        self.oblivious = oblivious_spray
        self.sp = strack_params or make_strack_params(net)
        self.rp = roce_params or make_roce_params(net)
        self.now = 0.0
        self.evq: list = []
        self.seq = itertools.count()
        self.flows: dict[int, Flow] = {}
        self.host_flows: dict[int, list] = {h: [] for h in range(topo.n_hosts)}
        self.host_rr: dict[int, int] = {h: 0 for h in range(topo.n_hosts)}
        self.total_drops = 0
        self.pause_log: list = []
        self.pump_pending: dict[int, float] = {}   # host -> scheduled t
        self.qdelay_log_threshold = qdelay_log_threshold
        self.on_flow_done: Optional[Callable] = None
        self.throughput_probe: Optional[Callable] = None
        self.ack_log: Optional[list] = None   # (t, flow, ecn, rtt) if enabled
        self.rx_bytes_log: Optional[list] = None  # (t, flow, bytes) if enabled
        self._fid = itertools.count()

        rate = net.rate_Bpus
        # Per-link propagation from the shared NetworkSpec delay model
        # (derived so the uncongested cross-ToR RTT == net.base_rtt_us,
        # exactly as the jitted fabric's per-hop pipeline realizes it).
        self.prop_us = net.hop_prop_effective_us
        prop = self.prop_us
        lossless = transport == "roce"
        kmin = net.ecn_kmin_bytes
        kmax = net.ecn_kmax_bytes
        if lossless:
            kmin = kmax = self.rp.ecn_kmin_bdp * net.bdp_bytes
        drop = None if lossless else net.drop_bytes

        # Switches
        self.tors = [Switch(self, f"tor{t}", switch_buffer_bytes, lossless)
                     for t in range(topo.n_tor)]
        self.spines = [Switch(self, f"sp{s}", switch_buffer_bytes, lossless)
                       for s in range(topo.n_spine)]
        # Queues
        self.nic_q = [Queue(self, f"nic{h}", rate, prop,
                            drain_host=h)
                      for h in range(topo.n_hosts)]
        self.tor_up = [[Queue(self, f"t{t}->s{s}", rate, prop,
                              kmin, kmax, drop, switch=self.tors[t])
                        for s in range(topo.n_spine)]
                       for t in range(topo.n_tor)]
        self.spine_down = [[Queue(self, f"s{s}->t{t}", rate, prop,
                                  kmin, kmax, drop, switch=self.spines[s])
                            for t in range(topo.n_tor)]
                           for s in range(topo.n_spine)]
        self.host_down = [Queue(self, f"t->h{h}", rate, prop,
                                kmin, kmax, drop,
                                switch=self.tors[topo.tor_of(h)])
                          for h in range(topo.n_hosts)]
        if log_queues:
            for t in range(topo.n_tor):
                for s in range(topo.n_spine):
                    self.tor_up[t][s].delay_log = []
                    self.spine_down[s][t].delay_log = []
            for h in range(topo.n_hosts):
                self.host_down[h].delay_log = []
        # PFC ingress registration: ingress port -> upstream queue
        for t in range(topo.n_tor):
            for h in range(t * topo.hosts_per_tor,
                           (t + 1) * topo.hosts_per_tor):
                self.tors[t].register_ingress(("h", h), self.nic_q[h])
            for s in range(topo.n_spine):
                self.tors[t].register_ingress(("s", s),
                                              self.spine_down[s][t])
                self.spines[s].register_ingress(("t", t), self.tor_up[t][s])

        # Chaos schedule (sim/faults.py): attach per-queue fault windows.
        # Window ticks convert to us via mtu_serialize_us (one fabric tick
        # = one MTU serialization slot); queue-row ids mirror the fabric's
        # layout so corruption PRNG keying matches across backends.
        self.faults = faults
        self.fault_seed = faults.seed32 if faults is not None else 0
        self.blackholed_pkts = 0
        self.corrupt_drops = 0
        self._flap_up: dict[int, list] = {}   # tor -> [(spine, t0us, t1us)]
        self._nic_flap: dict[int, list] = {}  # host -> [(t0us, t1us)]
        T, S = topo.n_tor, topo.n_spine
        for t in range(T):
            for s in range(S):
                self.tor_up[t][s].fault_row = t * S + s
                self.spine_down[s][t].fault_row = T * S + s * T + t
        for h in range(topo.n_hosts):
            self.host_down[h].fault_row = 2 * T * S + h
        if faults is not None:
            validate_faults(faults, topo)
            tick = net.mtu_serialize_us
            for (t, s, a, b) in faults.link_flaps:
                win = (a * tick, b * tick)
                self.tor_up[t][s].flap_wins += (win,)
                self.spine_down[s][t].flap_wins += (win,)
                self._flap_up.setdefault(t, []).append((s, *win))
            for (t, s, a, b) in faults.uplink_flaps:
                # up direction only (time-varying dead_links semantics)
                win = (a * tick, b * tick)
                self.tor_up[t][s].flap_wins += (win,)
                self._flap_up.setdefault(t, []).append((s, *win))
            for (h, a, b) in faults.host_flaps:
                self.host_down[h].flap_wins += ((a * tick, b * tick),)
                self._nic_flap.setdefault(h, []).append((a * tick, b * tick))
            for (t, s, a, b, c) in faults.link_degrade:
                win = (a * tick, b * tick, c)
                self.tor_up[t][s].degrade += (win,)
                self.spine_down[s][t].degrade += (win,)
            for (t, s, a, b, p) in faults.link_corrupt:
                win = (a * tick, b * tick, p)
                self.tor_up[t][s].cor_wins += (win,)
                self.spine_down[s][t].cor_wins += (win,)
            for (h, a, b, p) in faults.host_corrupt:
                self.host_down[h].cor_wins += ((a * tick, b * tick, p),)

    # ------------------------------------------------------------------ #
    def schedule(self, t, kind, payload):
        heapq.heappush(self.evq, (t, next(self.seq), kind, payload))

    def schedule_pump(self, t, host):
        """Deduplicated pump scheduling: at most one pending pump per host
        at or before any requested time (prevents event storms when many
        paced flows share a NIC)."""
        pending = self.pump_pending.get(host)
        if pending is not None and pending <= t + 1e-9:
            return
        self.pump_pending[host] = t
        heapq.heappush(self.evq, (t, next(self.seq), "pump", host))

    def add_flow(self, src, dst, msg_bytes, start_ts=0.0, meta=None) -> Flow:
        # RoCEv2 with QPS_PER_CONN > 1 ("optimized RoCEv2", paper Figs
        # 21-28): the message is striped over N QPs, each a single-path
        # sub-flow with its own entropy; the message completes when the
        # last QP completes.
        if self.transport == "roce" and self.rp.qps_per_conn > 1:
            n = self.rp.qps_per_conn
            parent = Flow(next(self._fid), src, dst, msg_bytes, start_ts,
                          meta)
            parts = [self._add_single(src, dst, msg_bytes / n, start_ts)
                     for _ in range(n)]
            parent._parts = parts
            for sub in parts:
                sub._parent = parent
            parent._remaining = n
            self.flows[parent.id] = parent
            return parent
        return self._add_single(src, dst, msg_bytes, start_ts, meta)

    def _add_single(self, src, dst, msg_bytes, start_ts=0.0,
                    meta=None) -> Flow:
        fid = next(self._fid)
        fl = Flow(fid, src, dst, msg_bytes, start_ts, meta)
        sp, rp, net = self.sp, self.rp, self.net
        if self.transport == "strack":
            fl.sender = ref.STrackSender(sp, fid, msg_bytes, start_ts)
            if self.oblivious:
                fl.sender.spray = _ObliviousSpray(sp, start_ts)
            fl.receiver = ref.STrackReceiver(sp, fl.sender.total_pkts)
        else:
            entropy = self.rng.randrange(1 << 16)
            fl.sender = ref.RoCESender(
                rp.dcqcn, fid, msg_bytes, net.mtu_bytes, net.rate_Bpus,
                entropy, rp.rto_us, window_bdp_pkts=net.bdp_pkts,
                now=start_ts)
            fl.receiver = ref.RoCEReceiver(
                fl.sender.total_pkts, rp.ack_coalesce_pkts,
                rp.dcqcn.cnp_interval_us)
        self.flows[fid] = fl
        self.host_flows[src].append(fl)
        self.schedule_pump(start_ts, src)
        self._arm_timer(fl, start_ts)
        return fl

    # ------------------------------------------------------------------ #
    def _route(self, pkt, src, dst):
        """Hop list (queue, tag) a packet takes from src NIC to dst host."""
        topo = self.topo
        st, dt = topo.tor_of(src), topo.tor_of(dst)
        hops = []
        if st == dt:
            hops.append((self.host_down[dst], ("h", src)))
        else:
            s = self._pick_spine(src, dst, pkt.entropy)
            hops.append((self.tor_up[st][s], ("h", src)))
            hops.append((self.spine_down[s][dt], ("t", st)))
            hops.append((self.host_down[dst], ("s", s)))
        return hops

    def _pick_spine(self, src, dst, entropy):
        """ECMP over the uplinks live *now*: flapped uplinks leave the
        candidate set while their window is active (routing reconverges —
        the fabric's time-varying live mask does the same), and rejoin
        when the window closes."""
        topo = self.topo
        st = topo.tor_of(src)
        flaps = self._flap_up.get(st)
        if flaps:
            now = self.now
            down = {s for (s, a, b) in flaps if a <= now < b}
            if down:
                live = [s for s in topo.live_up[st] if s not in down]
                if live:
                    return live[_mix(src, dst, entropy) % len(live)]
        return topo.ecmp_spine(src, dst, entropy)

    def _launch(self, pkt, now):
        """Send pkt from its src host NIC through the fabric to pkt.dst."""
        if self._nic_flap and pkt.kind in (ref.DATA, ref.PROBE):
            # flapped host NIC: the sender committed its send state but
            # the packet never reaches the wire (RTO recovers it)
            wins = self._nic_flap.get(pkt.src)
            if wins and any(a <= now < b for a, b in wins):
                self.blackholed_pkts += 1
                return
        pkt._route = self._route(pkt, pkt.src, pkt.dst)
        pkt._hop = 0
        self.nic_q[pkt.src].enqueue(pkt, ("fabric", pkt), now)

    def _pump(self, host, now):
        """Pull-based NIC: clock out packets while the wire is free."""
        nic = self.nic_q[host]
        if nic.busy:
            return
        flows = self.host_flows[host]
        n = len(flows)
        if n == 0:
            return
        start = self.host_rr[host]
        for i in range(n):
            fl = flows[(start + i) % n]
            snd = fl.sender
            if snd.done():
                continue
            if fl.start_ts > now + 1e-9:
                # future-dated flow (an open-loop arrival): a shared
                # host's pump must not clock it out early; re-arm for
                # its start time (the dedup in schedule_pump may have
                # swallowed the pump add_flow armed)
                self.schedule_pump(fl.start_ts, host)
                continue
            if self.transport == "strack":
                if not snd.can_send():
                    continue
                pkt = snd.next_packet(now)
            else:
                if not snd.can_send(now):
                    # paced: re-pump at next_send_ts if that's the blocker
                    if (not snd.done()
                            and snd.psn_next < snd.total_pkts
                            and (snd.psn_next - snd.snd_una)
                            < snd.window_pkts
                            and snd.next_send_ts > now):
                        self.schedule_pump(snd.next_send_ts, host)
                    continue
                pkt = snd.next_packet(now)
            if pkt is None:
                continue
            pkt.src, pkt.dst = fl.src, fl.dst
            self.host_rr[host] = (start + i + 1) % n
            self._launch(pkt, now)
            return

    # ------------------------------------------------------------------ #
    def _arm_timer(self, fl, now):
        dl = fl.sender.next_timer_deadline()
        if dl != math.inf:
            fl.timer_seq += 1
            self.schedule(max(dl, now + 1e-3), "timer", (fl, fl.timer_seq))

    def _on_timer(self, fl, seq, now):
        if seq != fl.timer_seq or fl.sender.done():
            return
        if self.transport == "strack":
            probe = fl.sender.on_timer(now)
            if probe is not None:
                probe.src, probe.dst = fl.src, fl.dst
                self._launch(probe, now)
        else:
            fl.sender.on_timer(now)
        self.schedule_pump(now, fl.src)
        self._arm_timer(fl, now)

    def _deliver(self, pkt, now):
        """Packet reached an endpoint host."""
        fl = self.flows[pkt.flow]
        if pkt.kind in (ref.DATA, ref.PROBE):
            out = fl.receiver.on_data(pkt, now)
            if out is None:
                return
            outs = out if isinstance(out, list) else [out]
            for o in outs:
                o.src, o.dst = fl.dst, fl.src
                self._launch(o, now)
        else:  # SACK / NACK / CNP back at the sender
            was_done = fl.sender.done()
            if self.ack_log is not None and pkt.kind == ref.SACK:
                self.ack_log.append((now, pkt.flow, pkt.ecn, now - pkt.ts))
            if self.rx_bytes_log is not None and pkt.kind == ref.SACK:
                self.rx_bytes_log.append((now, pkt.flow, pkt.bytes_recvd))
            if self.transport == "strack":
                fl.sender.on_sack(pkt, now)
            else:
                fl.sender.on_ack(pkt, now)
            self._arm_timer(fl, now)
            self.schedule_pump(now, fl.src)
            if fl.sender.done() and not was_done:
                parent = getattr(fl, "_parent", None)
                if parent is not None:
                    parent._remaining -= 1
                    if parent._remaining == 0:
                        parent._done_ts = now
                        if self.on_flow_done:
                            self.on_flow_done(parent, now)
                elif self.on_flow_done:
                    self.on_flow_done(fl, now)

    # ------------------------------------------------------------------ #
    def run(self, until: float = math.inf, max_events: int = 200_000_000):
        evq = self.evq
        n = 0
        while evq and n < max_events:
            t, seq, kind, payload = heapq.heappop(evq)
            if t > until:
                # keep the event for a later run(until=...) call
                heapq.heappush(evq, (t, seq, kind, payload))
                self.now = until
                return
            self.now = t
            n += 1
            if kind == "deq":
                payload.service(t)
            elif kind == "hop":
                pkt, nh = payload
                if nh[0] == "fabric":
                    self._advance(pkt, t)
                else:
                    self._deliver(pkt, t)
            elif kind == "pump":
                if self.pump_pending.get(payload) is not None \
                        and self.pump_pending[payload] <= t + 1e-9:
                    self.pump_pending.pop(payload, None)
                self._pump(payload, t)
            elif kind == "timer":
                fl, seq = payload
                self._on_timer(fl, seq, t)
            elif kind == "pause":
                payload.pause(t)
            elif kind == "resume":
                payload.resume(t)

    def _advance(self, pkt, now):
        """Move pkt to its next fabric hop or deliver at host."""
        hops = pkt._route
        i = pkt._hop
        if i < len(hops):
            q, ingress = hops[i]
            pkt._hop = i + 1
            pkt._ingress = ingress
            q.enqueue(pkt, ("fabric", pkt) if i + 1 < len(hops)
                      else ("host", pkt.dst), now)
            # after the NIC, subsequent "hop" events carry ("fabric", pkt)
        else:
            self._deliver(pkt, now)

    # metrics helpers ---------------------------------------------------- #
    def all_queue_delay_logs(self):
        logs = []
        for t in range(self.topo.n_tor):
            for s in range(self.topo.n_spine):
                for q in (self.tor_up[t][s], self.spine_down[s][t]):
                    if q.delay_log:
                        logs.extend(q.delay_log)
        for h in range(self.topo.n_hosts):
            if self.host_down[h].delay_log:
                logs.extend(self.host_down[h].delay_log)
        return sorted(logs)

    def max_fct(self):
        return max(fl.fct for fl in self.flows.values()
                   if fl.fct is not None)


class _ObliviousSpray:
    """Oblivious packet spray baseline: pure round-robin over entropies."""

    __slots__ = ("p", "rr")

    def __init__(self, p, now=0.0):
        self.p = p
        self.rr = 0

    def update_ecn_bitmap(self, ecn, path_id):
        pass

    def choose_path(self, cwnd_pkts, now):
        self.rr = (self.rr + 1) % self.p.max_paths
        return self.rr
