"""Fully-jitted time-stepped STrack simulator (single-bottleneck incast).

One XLA program simulates N STrack flows sharing one egress queue — the
paper's incast scenario (Figs. 16-20) — with the *same* vmapped flow
engines (`repro.core.transport`) the framework exposes as its composable
module.  1 tick = 1 MTU serialization time at the bottleneck:

  * each tick every flow may clock out <=1 packet (NIC rate == link rate),
  * the queue serves 1 packet/tick, marks egress ECN on residual depth
    between Kmin..Kmax (deterministic ramp), silently drops beyond 5 BDP,
  * the receiver coalesces SACKs; at most one delivery (hence one SACK)
    per tick rides the fixed-latency return pipe.

Everything is fixed-shape; the whole run is a single lax.scan.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core import transport as tp
from ..core import reliability as rel
from ..core.params import NetworkSpec, STrackParams, make_strack_params
from ..core.reliability import SackMsg


class QueueState(NamedTuple):
    flow: jax.Array     # i32[cap]
    psn: jax.Array      # i32[cap]
    ts: jax.Array       # f32[cap]
    probe: jax.Array    # bool[cap]
    entropy: jax.Array  # i32[cap]
    head: jax.Array     # i32
    size: jax.Array     # i32


class SimState(NamedTuple):
    flows: tp.FlowState          # vmapped [N]
    rcv: rel.ReceiverState       # vmapped [N]
    q: QueueState
    sack_pipe: SackMsg           # [H] slots (+ flow field below)
    sack_flow: jax.Array         # i32[H]
    drops: jax.Array             # i32
    delivered: jax.Array         # f32[N]


def _empty_sack(p: STrackParams, h: int) -> SackMsg:
    z = lambda dt: jnp.zeros((h,), dt)
    return SackMsg(valid=z(bool), epsn=z(jnp.int32), sack_base=z(jnp.int32),
                   sack_bits=jnp.zeros((h, p.sack_bitmap_bits), bool),
                   bytes_recvd=z(jnp.float32), ooo_cnt=z(jnp.int32),
                   ecn=z(bool), entropy=z(jnp.int32), ts=z(jnp.float32),
                   probe_reply=z(bool))


@dataclasses.dataclass(frozen=True)
class IncastConfig:
    n_flows: int = 32
    msg_bytes: float = 2 * 2 ** 20
    net: NetworkSpec = dataclasses.field(default_factory=NetworkSpec)
    fwd_delay_ticks: int = 48     # sender->queue (~4us at 400G/4KB ticks)
    ret_delay_ticks: int = 48     # receiver->sender
    max_paths: int = 64


def run_incast(cfg: IncastConfig, n_ticks: int):
    """Returns per-tick metrics dict + final state (all jitted)."""
    net = cfg.net
    p = make_strack_params(net, max_paths=cfg.max_paths)
    N = cfg.n_flows
    tick_us = net.mtu_serialize_us
    total_pkts = int(math.ceil(cfg.msg_bytes / net.mtu_bytes))
    qcap = int(net.drop_bytes / net.mtu_bytes) + 2
    kmin_p = net.ecn_kmin_bytes / net.mtu_bytes
    kmax_p = net.ecn_kmax_bytes / net.mtu_bytes
    H = cfg.ret_delay_ticks + cfg.fwd_delay_ticks + 2

    flows = jax.vmap(lambda _: tp.init_flow(p, total_pkts))(jnp.arange(N))
    rcv = jax.vmap(lambda _: rel.init_receiver(total_pkts))(jnp.arange(N))
    q = QueueState(flow=jnp.full((qcap,), -1, jnp.int32),
                   psn=jnp.zeros((qcap,), jnp.int32),
                   ts=jnp.zeros((qcap,), jnp.float32),
                   probe=jnp.zeros((qcap,), bool),
                   entropy=jnp.zeros((qcap,), jnp.int32),
                   head=jnp.zeros((), jnp.int32),
                   size=jnp.zeros((), jnp.int32))
    st = SimState(flows=flows, rcv=rcv, q=q,
                  sack_pipe=_empty_sack(p, H),
                  sack_flow=jnp.full((H,), -1, jnp.int32),
                  drops=jnp.zeros((), jnp.int32),
                  delivered=jnp.zeros((N,), jnp.float32))

    def tick_fn(st: SimState, t):
        now = t.astype(jnp.float32) * tick_us
        q = st.q

        # ---- 1. serve one packet from the queue -> receiver -------------
        has_pkt = q.size > 0
        idx = q.head % qcap
        f = q.flow[idx]
        residual = jnp.maximum(q.size - 1, 0).astype(jnp.float32)
        frac = jnp.clip((residual - kmin_p) / jnp.maximum(kmax_p - kmin_p,
                                                          1e-9), 0.0, 1.0)
        # deterministic ECN ramp (hash of tick as dither)
        dither = (jnp.abs(jnp.sin(t.astype(jnp.float32) * 12.9898)) * 1.0)
        ecn = has_pkt & (frac > dither * 0.999)
        fc = jnp.clip(f, 0, N - 1)
        rw = jax.tree.map(lambda a: a[fc], st.rcv)
        rw2, sack = rel.receiver_on_data(
            rw, p, q.psn[idx], jnp.float32(net.mtu_bytes), ecn,
            q.entropy[idx], q.ts[idx], q.probe[idx])
        rw2 = jax.tree.map(lambda n_, o: jnp.where(has_pkt, n_, o), rw2, rw)
        rcv = jax.tree.map(lambda all_, one: all_.at[fc].set(one), st.rcv,
                           rw2)
        sack_valid = sack.valid & has_pkt
        # fwd delay is folded into the return leg: base RTT = fwd+ret+1
        slot = (t + cfg.ret_delay_ticks + cfg.fwd_delay_ticks) % H
        pipe = jax.tree.map(
            lambda pv, sv: pv.at[slot].set(jnp.where(sack_valid, sv,
                                                     pv[slot])),
            st.sack_pipe, sack)
        sack_flow = st.sack_flow.at[slot].set(
            jnp.where(sack_valid, fc, jnp.int32(-1)))
        q = q._replace(head=jnp.where(has_pkt, q.head + 1, q.head),
                       size=jnp.where(has_pkt, q.size - 1, q.size))
        delivered = st.delivered.at[fc].add(
            jnp.where(has_pkt & ~q.probe[idx], net.mtu_bytes, 0.0))

        # ---- 2. deliver due SACK to its sender ---------------------------
        cur = t % H
        due_flow = sack_flow[cur]
        due = jax.tree.map(lambda a: a[cur], pipe)
        have_sack = due_flow >= 0

        def apply_sack(fs_all):
            fcl = jnp.clip(due_flow, 0, N - 1)
            one = jax.tree.map(lambda a: a[fcl], fs_all)
            due_ok = due._replace(valid=due.valid & have_sack)
            one2 = tp.flow_on_sack(one, p, due_ok, now)
            return jax.tree.map(lambda al, o: al.at[fcl].set(o), fs_all,
                                one2)
        flows = apply_sack(st.flows)
        sack_flow = sack_flow.at[cur].set(-1)

        # ---- 3. timers (probes / RTO), every 8 ticks ---------------------
        def timers(fl):
            fl2, probe_tx = jax.vmap(
                lambda f_: tp.flow_on_timer(f_, p, now))(fl)
            return fl2, probe_tx
        run_timers = (t % 8) == 0
        flows, probe_tx = jax.lax.cond(
            run_timers, timers,
            lambda fl: (fl, tp.TxPacket(
                valid=jnp.zeros((N,), bool), psn=jnp.zeros((N,), jnp.int32),
                entropy=jnp.zeros((N,), jnp.int32),
                is_rtx=jnp.zeros((N,), bool),
                is_probe=jnp.zeros((N,), bool))), flows)

        # ---- 4. sends: every flow may clock out one packet --------------
        flows, tx = jax.vmap(lambda f_: tp.flow_next_packet(f_, p, now))(
            flows)

        # enqueue probes + data (fori over flows; each appends <=2)
        def enq(i, carry):
            q, drops = carry

            def push(q, drops, psn, probe, entropy):
                full = q.size >= qcap - 1
                # silent drop when queue exceeds the 5 BDP threshold
                drop = q.size.astype(jnp.float32) >= (qcap - 2)
                pos = (q.head + q.size) % qcap
                qn = QueueState(
                    flow=q.flow.at[pos].set(jnp.int32(i)),
                    psn=q.psn.at[pos].set(psn),
                    ts=q.ts.at[pos].set(now),
                    probe=q.probe.at[pos].set(probe),
                    entropy=q.entropy.at[pos].set(entropy),
                    head=q.head,
                    size=q.size + 1)
                qn = jax.tree.map(lambda n_, o: jnp.where(drop | full, o, n_),
                                  qn, q)
                return qn, drops + jnp.where(drop | full, 1, 0)

            send = tx.valid[i]
            qd, dd = push(q, drops, tx.psn[i], jnp.zeros((), bool),
                          tx.entropy[i])
            q = jax.tree.map(lambda n_, o: jnp.where(send, n_, o), qd, q)
            drops = jnp.where(send, dd, drops)
            sendp = probe_tx.valid[i]
            qp, dp = push(q, drops, probe_tx.psn[i], jnp.ones((), bool),
                          probe_tx.entropy[i])
            q = jax.tree.map(lambda n_, o: jnp.where(sendp, n_, o), qp, q)
            drops = jnp.where(sendp, dp, drops)
            return (q, drops)

        q, drops = jax.lax.fori_loop(0, N, enq, (q, st.drops))

        new_st = SimState(flows=flows, rcv=rcv, q=q, sack_pipe=pipe,
                          sack_flow=sack_flow, drops=drops,
                          delivered=delivered)
        metrics = {
            "queue_pkts": q.size,
            "drops": drops,
            "cwnd_mean": jnp.mean(flows.cc.cwnd),
            "done": jnp.sum(jax.vmap(tp.flow_done)(flows)),
            "delivered": delivered,
        }
        return new_st, metrics

    @jax.jit
    def run(st):
        return jax.lax.scan(tick_fn, st, jnp.arange(n_ticks, dtype=jnp.int32))

    final, metrics = run(st)
    metrics["tick_us"] = tick_us
    metrics["target_qdelay_pkts"] = p.target_qdelay_us / tick_us
    return final, metrics
