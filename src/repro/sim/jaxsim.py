"""Fully-jitted single-bottleneck incast — the 1-queue special case of
``fabric.py``.

One XLA program simulates N STrack flows sharing one egress queue — the
paper's incast scenario (Figs. 16-20) — with the *same* vmapped flow
engines (``repro.core.transport``) the framework exposes as its composable
module.  Since the multi-queue fat-tree refactor this module is a thin
wrapper: the incast is a degenerate fat-tree (one ToR, one spine, N+1
hosts) whose only contended queue is the destination host's downlink, run
on :func:`repro.sim.fabric.run_fabric`.  1 tick = 1 MTU serialization time
at the bottleneck:

  * each tick every flow may clock out <=1 packet (NIC rate == link rate),
  * the queue serves 1 packet/tick, marks egress ECN on residual depth
    between Kmin..Kmax (deterministic ramp), silently drops beyond 5 BDP,
  * SACKs ride the fixed-latency return pipe (fwd delay folded in) — the
    ``delay_ticks`` override keeps this module on the fabric's legacy
    "folded" delay model; the multi-queue fabric itself defaults to the
    per-hop latency pipeline (``FabricConfig.ack_path="perhop"``).

Everything is fixed-shape; the whole run is a single lax.scan.  See the
module map in ``fabric.py`` for how the sim/ package fits together.
"""
from __future__ import annotations

import dataclasses

from ..core.params import NetworkSpec
from .fabric import FabricConfig, run_fabric
from .topology import FatTree


@dataclasses.dataclass(frozen=True)
class IncastConfig:
    n_flows: int = 32
    msg_bytes: float = 2 * 2 ** 20
    net: NetworkSpec = dataclasses.field(default_factory=NetworkSpec)
    fwd_delay_ticks: int = 48     # sender->queue (~4us at 400G/4KB ticks)
    ret_delay_ticks: int = 48     # receiver->sender
    max_paths: int = 64


def run_incast(cfg: IncastConfig, n_ticks: int):
    """Returns per-tick metrics dict + final state (all jitted).

    ``metrics["queue_pkts"]`` is the bottleneck (destination downlink)
    occupancy per tick, matching the pre-fabric single-queue simulator.
    """
    n = cfg.n_flows
    # Degenerate fat-tree: all hosts on one ToR, so every packet goes
    # straight into the destination host's downlink queue — the bottleneck.
    topo = FatTree(n_tor=1, hosts_per_tor=n + 1, n_spine=1)
    flows = [(i + 1, 0, float(cfg.msg_bytes)) for i in range(n)]
    fcfg = FabricConfig(
        net=cfg.net, max_paths=cfg.max_paths,
        delay_ticks=cfg.fwd_delay_ticks + cfg.ret_delay_ticks)
    final, metrics = run_fabric(topo, flows, n_ticks, fcfg)
    bottleneck = metrics["queue_ids"]["host_down"](0)
    metrics["queue_pkts"] = metrics["qsize"][:, bottleneck]
    # legacy single-queue contract: "drops" is the per-tick cumulative
    # trace here (the fabric now reports the exact final scalar under
    # that key and the trace as "drops_trace")
    metrics["drops"] = metrics["drops_trace"]
    return final, metrics
