"""Multi-tenant traffic generator + soak driver (the observatory's feed).

The paper's headline claims are about *sustained* operation of a shared
fabric: overlapping training jobs (collectives with dependency chains)
contending with bursty inference/incast traffic.  This module generates
that mix as ordinary :class:`~repro.sim.workloads.Message` traces — so
both backends run it unchanged — and drives long-horizon soaks by
chaining ``run()`` epochs on the warp fabric.

Determinism: every random draw comes from a counter-based splitmix64
stream keyed by ``(seed, tenant, epoch, flow, channel)``.  No host
randomness, no hidden state — the same ``(spec, seed, epoch)`` always
emits the bit-identical trace, and a different seed reshuffles arrivals
and placements without touching the trace *structure* (message count,
dependency edges, groups).  Structure invariance across epochs is what
lets every soak epoch reuse ONE compiled fabric program: src/dst, sizes
and arrival ticks are program *data*.

Tenants:

  * :class:`TrainingJob` — ``steps`` chained collective instances
    (ring / dbt / hd / a2a via ``repro.collective.algorithms``) on a
    placement that stays fixed across epochs (``multi_job(hosts=...)``
    reuse), entering the fabric at ``start_tick`` (the ``arrival``
    field; dependency edges chain step ``s`` on step ``s-1``).
  * :class:`InferenceTenant` — open-loop incast-style load: ``n_flows``
    small messages per epoch with Poisson-style interarrival ticks
    (inverse-CDF exponential on splitmix64 uniforms) into a small set
    of frontend target hosts.

Each tenant is one ``group``, so the fabric's ``summarize`` attributes
FCT percentiles per tenant (``tenant_fct``), and :func:`soak` folds the
per-epoch counters (drops, pauses, ECN marks, retransmits, queue depth)
into a :class:`~repro.obs.metrics.MetricsRegistry` for the Prometheus
exporter.  See docs/observatory.md.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.params import NetworkSpec
from .faults import FaultSpec
from .topology import FatTree
from .workloads import Message, RunConfig, Scenario, run

# --------------------------------------------------------------------------- #
# Counter-based PRNG: splitmix64 over a (seed, *counters) key
# --------------------------------------------------------------------------- #

_MASK64 = (1 << 64) - 1
_GOLDEN = 0x9E3779B97F4A7C15


def splitmix64(x: int) -> int:
    """One splitmix64 output step (Steele et al.): u64 -> u64."""
    x = (x + _GOLDEN) & _MASK64
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK64
    return (z ^ (z >> 31)) & _MASK64


def _u64(seed: int, *counters: int) -> int:
    """Stateless draw: hash the (seed, counters...) key path."""
    state = splitmix64(seed & _MASK64)
    for c in counters:
        state = splitmix64(state ^ ((c & _MASK64) * _GOLDEN & _MASK64))
    return state


def _u01(seed: int, *counters: int) -> float:
    """Uniform in [0, 1) with 53 usable bits."""
    return (_u64(seed, *counters) >> 11) / float(1 << 53)


def _shuffled(n: int, seed: int, *counters: int) -> List[int]:
    """Deterministic Fisher-Yates permutation of range(n)."""
    out = list(range(n))
    for i in range(n - 1, 0, -1):
        j = _u64(seed, *counters, i) % (i + 1)
        out[i], out[j] = out[j], out[i]
    return out


# --------------------------------------------------------------------------- #
# Tenant specs
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class TrainingJob:
    """One training tenant: ``steps`` chained collectives on a fixed
    placement.  ``algo_kw`` is a tuple of (key, value) pairs (hashable)
    passed to the collective generator (e.g. ``(("chunk", 32768),)``).
    ``hosts`` pins the placement explicitly; None lets the generator
    carve a disjoint slice of the (seed-shuffled) host list."""

    name: str
    algo: str = "ring"
    ranks: int = 8
    collective_bytes: float = 256 * 2 ** 10
    steps: int = 1
    start_tick: int = 0
    algo_kw: Tuple[Tuple[str, object], ...] = ()
    hosts: Optional[Tuple[int, ...]] = None


@dataclass(frozen=True)
class InferenceTenant:
    """Open-loop bursty tenant: ``n_flows`` messages per epoch with
    exponential (Poisson-process) interarrival ticks into ``n_targets``
    frontend hosts.  ``size_jitter`` scales each message's size by a
    uniform factor in [1-j, 1+j]."""

    name: str
    n_flows: int = 64
    mean_interarrival_ticks: float = 8.0
    size_bytes: float = 16 * 2 ** 10
    size_jitter: float = 0.0
    n_targets: int = 1
    targets: Optional[Tuple[int, ...]] = None
    start_tick: int = 0


# --------------------------------------------------------------------------- #
# The generator
# --------------------------------------------------------------------------- #

def _job_messages(job: TrainingJob, tenant_idx: int, job_hosts: Sequence[int],
                  n_hosts: int, mid_base: int) -> List[Message]:
    from ..collective.algorithms import multi_job  # cycle: algorithms ← sim
    msgs, placement = multi_job(job.algo, 1, job.ranks, n_hosts,
                                job.collective_bytes, hosts=list(job_hosts),
                                **dict(job.algo_kw))
    per_step = len(msgs)
    out: List[Message] = []
    for s in range(job.steps):
        base = mid_base + s * per_step
        prev = mid_base + (s - 1) * per_step
        for m in msgs:
            deps = tuple(d + base for d in m.deps)
            if s > 0:
                # chain the steps: each message also waits for its
                # same-index message of the previous step
                deps = deps + (prev + m.mid,)
            out.append(Message(
                mid=base + m.mid, src=placement[m.src],
                dst=placement[m.dst], size=m.size, deps=deps,
                group=tenant_idx, arrival=job.start_tick))
    return out


def _burst_messages(ten: InferenceTenant, tenant_idx: int,
                    targets: Sequence[int], n_hosts: int, mid_base: int,
                    seed: int, epoch: int) -> List[Message]:
    out: List[Message] = []
    t = float(ten.start_tick)
    for k in range(ten.n_flows):
        u = _u01(seed, tenant_idx, epoch, k, 0)
        # inverse-CDF exponential, clamped to >= 1 tick so arrivals
        # strictly advance (an open-loop process, never a thundering herd
        # at tick 0 unless the mean asks for it)
        t += max(1.0, round(-ten.mean_interarrival_ticks
                            * math.log(1.0 - u)))
        dst = targets[_u64(seed, tenant_idx, epoch, k, 1) % len(targets)]
        src = _u64(seed, tenant_idx, epoch, k, 2) % n_hosts
        if src == dst:
            src = (src + 1) % n_hosts
        size = ten.size_bytes
        if ten.size_jitter:
            j = ten.size_jitter * (2.0 * _u01(seed, tenant_idx, epoch,
                                              k, 3) - 1.0)
            size = max(1.0, size * (1.0 + j))
        out.append(Message(mid=mid_base + k, src=src, dst=dst,
                           size=float(size), group=tenant_idx,
                           arrival=int(t)))
    return out


def mixed_scenario(topo: FatTree, jobs: Sequence[TrainingJob],
                   tenants: Sequence[InferenceTenant],
                   net: Optional[NetworkSpec] = None, seed: int = 0,
                   epoch: int = 0) -> Tuple[Scenario, Dict[int, str]]:
    """One epoch of the multi-tenant mix as a Scenario.

    Returns ``(scenario, tenant_of_group)`` where group ``g`` in the
    scenario (and in ``summarize()['tenant_fct']``) belongs to tenant
    ``tenant_of_group[g]``.  Placements and targets depend only on
    ``seed`` (stable across epochs — the placement-reuse contract);
    burst arrivals, sources and sizes depend on ``(seed, epoch)``; the
    trace *structure* (message count, deps, groups) depends on neither,
    so every epoch of a soak compiles to the same fabric program.
    """
    net = net or NetworkSpec()
    names = [j.name for j in jobs] + [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names: {names}")
    # seed-keyed placement pool; jobs take disjoint slices off the front,
    # burst targets come off the back so frontends avoid the job ranks
    # when capacity allows
    pool = _shuffled(topo.n_hosts, seed, 0)
    cursor = 0
    messages: List[Message] = []
    tenant_of_group: Dict[int, str] = {}
    for g, job in enumerate(jobs):
        if job.hosts is not None:
            job_hosts = list(job.hosts)
        else:
            if cursor + job.ranks > topo.n_hosts:
                raise ValueError(f"job {job.name!r}: not enough hosts "
                                 f"({cursor + job.ranks} needed, "
                                 f"{topo.n_hosts} available)")
            job_hosts = pool[cursor:cursor + job.ranks]
            cursor += job.ranks
        messages += _job_messages(job, g, job_hosts, topo.n_hosts,
                                  len(messages))
        tenant_of_group[g] = job.name
    back = topo.n_hosts
    for i, ten in enumerate(tenants):
        g = len(jobs) + i
        if ten.targets is not None:
            targets = list(ten.targets)
        else:
            n_t = max(1, min(ten.n_targets, topo.n_hosts))
            targets = pool[max(cursor, back - n_t):back]
            targets = targets or pool[-n_t:]
            back = max(cursor, back - n_t)
        messages += _burst_messages(ten, g, targets, topo.n_hosts,
                                    len(messages), seed, epoch)
        tenant_of_group[g] = ten.name
    sc = Scenario(name=f"mixed_s{seed}e{epoch}", topo=topo, net=net,
                  messages=tuple(messages))
    return sc, tenant_of_group


# --------------------------------------------------------------------------- #
# The soak driver: chained run() epochs, carried counters
# --------------------------------------------------------------------------- #

_COUNTERS = ("drops", "pauses", "ecn_marks", "retransmits", "rto_fires",
             "sack_recoveries", "gbn_rewinds", "blackholed_pkts",
             "corrupt_drops")


def inert_faults_like(fs: FaultSpec) -> FaultSpec:
    """A FaultSpec with every window collapsed to [0, 0) — same
    ``shape_key`` (so the same compiled program serves it), zero effect.
    Chaos soaks use it to run clean epochs through the faulted program."""
    return FaultSpec(
        link_flaps=tuple((t, s, 0, 0) for (t, s, _a, _b) in fs.link_flaps),
        uplink_flaps=tuple((t, s, 0, 0)
                           for (t, s, _a, _b) in fs.uplink_flaps),
        host_flaps=tuple((h, 0, 0) for (h, _a, _b) in fs.host_flaps),
        link_degrade=tuple((t, s, 0, 0, c)
                           for (t, s, _a, _b, c) in fs.link_degrade),
        link_corrupt=tuple((t, s, 0, 0, p)
                           for (t, s, _a, _b, p) in fs.link_corrupt),
        host_corrupt=tuple((h, 0, 0, p)
                           for (h, _a, _b, p) in fs.host_corrupt),
        seed=fs.seed)


def record_epoch(reg, res: dict, tenant_of_group: Dict[int, str]) -> None:
    """Fold one epoch's summary into a MetricsRegistry (strack_* names;
    catalogue in docs/observatory.md)."""
    reg.declare("strack_epochs_total", "soak epochs completed", "counter")
    reg.inc("strack_epochs_total")
    for key in _COUNTERS:
        reg.declare(f"strack_{key}_total",
                    f"fabric {key.replace('_', ' ')} across epochs",
                    "counter")
        reg.inc(f"strack_{key}_total", float(res.get(key, 0)))
    reg.declare("strack_unfinished", "messages unfinished in the last "
                "epoch (0 = every epoch drained)", "gauge")
    reg.set("strack_unfinished", float(res.get("unfinished", 0)))
    reg.declare("strack_qdepth_max_pkts",
                "deepest switch queue of the last epoch (packets)",
                "gauge")
    reg.declare("strack_qdepth_p99_pkts",
                "p99 over queues of per-queue max depth, last epoch",
                "gauge")
    reg.set("strack_qdepth_max_pkts", float(res.get("qdepth_max_pkts", 0)))
    reg.set("strack_qdepth_p99_pkts", float(res.get("qdepth_p99_pkts", 0)))
    reg.declare("strack_fct_us", "per-tenant FCT percentiles of the last "
                "epoch (us)", "gauge")
    reg.declare("strack_messages_total", "messages finished per tenant",
                "counter")
    for g, row in (res.get("tenant_fct") or {}).items():
        tenant = tenant_of_group.get(g, str(g))
        for q in ("p50", "p99", "avg", "max"):
            v = row.get(q, float("nan"))
            reg.set("strack_fct_us", v, tenant=tenant, quantile=q)
        reg.inc("strack_messages_total",
                float(row["count"] - row["unfinished"]), tenant=tenant)


def soak(topo: FatTree, jobs: Sequence[TrainingJob],
         tenants: Sequence[InferenceTenant], epochs: int = 10,
         net: Optional[NetworkSpec] = None, seed: int = 0,
         cfg: Optional[RunConfig] = None, n_ticks: Optional[int] = None,
         registry=None, out_path: Optional[str] = None,
         chaos=None, verbose: bool = False) -> dict:
    """Long-horizon mixed-workload soak: ``epochs`` chained ``run()``
    segments on the warp fabric, counters carried across epochs.

    Every epoch re-samples the open-loop burst arrivals (epoch-keyed
    PRNG streams) but keeps the trace structure and tick horizon fixed,
    so the fabric compiles ONE program for the whole soak (asserted by
    the returned ``program_builds``).  ``registry`` (a
    :class:`~repro.obs.metrics.MetricsRegistry`) accumulates Prometheus
    metrics per epoch; ``out_path`` additionally dumps the rendered
    exposition after every epoch (so an exporter serving the file shows
    the soak live) and at the end.

    ``chaos`` turns on chaos epochs: a single :class:`FaultSpec` (every
    epoch faulted) or a per-epoch sequence where ``None`` entries mean a
    clean epoch.  Fault *values* are program data, so every entry must
    share one ``shape_key`` — clean epochs run the same compiled program
    through an inert schedule (:func:`inert_faults_like`) and the soak
    still compiles exactly one program.  Per-tenant p99 FCT from chaos
    epochs is ratioed against clean epochs into the
    ``strack_fct_degradation_ratio`` gauge (and the returned
    ``per_tenant[...]["degradation_p99"]``).
    """
    from . import fabric
    net = net or NetworkSpec()
    cfg = cfg or RunConfig()
    if cfg.backend != "fabric":
        raise ValueError("soak() drives the warp fabric; use run() "
                         "directly for one-shot oracle runs")
    epochs = int(epochs)
    if epochs < 1:
        raise ValueError("epochs must be >= 1")
    # normalize the chaos schedule to one FaultSpec per epoch (None when
    # chaos is off entirely); clean epochs get an inert same-shape spec
    chaos_flags = [False] * epochs
    epoch_faults: List[Optional[FaultSpec]] = [None] * epochs
    if chaos is not None:
        specs = ([chaos] * epochs if isinstance(chaos, FaultSpec)
                 else list(chaos))
        if len(specs) != epochs:
            raise ValueError(f"chaos schedule has {len(specs)} entries "
                             f"for {epochs} epochs")
        proto = next((fs for fs in specs if fs is not None), None)
        if proto is None:
            raise ValueError("chaos schedule is all-None; pass chaos=None "
                             "for a clean soak")
        inert = inert_faults_like(proto)
        for e, fs in enumerate(specs):
            if fs is None:
                epoch_faults[e] = inert
            else:
                if fs.shape_key != proto.shape_key:
                    raise ValueError(
                        f"chaos epoch {e} has shape_key {fs.shape_key}, "
                        f"expected {proto.shape_key}: every epoch must "
                        f"share one fault shape so ONE program serves "
                        f"the soak")
                epoch_faults[e] = fs
                chaos_flags[e] = fs.last_edge > 0
    scs = [mixed_scenario(topo, jobs, tenants, net=net, seed=seed, epoch=e)
           for e in range(epochs)]
    if n_ticks is None:
        # one fixed horizon covering every epoch's arrivals + critical
        # path — a fixed horizon is what keeps the program cacheable.
        # Chaos epochs extend it past the last fault edge so recovery
        # completes inside the same horizon.
        n_ticks = max(sc.default_ticks() for sc, _ in scs)
        last = max((fs.last_edge for fs in epoch_faults
                    if fs is not None), default=0)
        if last > 0:
            n_ticks = max(n_ticks, last + max(
                sc.default_ticks() for sc, _ in scs))
    cfg = replace(cfg, n_ticks=int(n_ticks))
    totals = {k: 0 for k in _COUNTERS}
    totals["unfinished"] = 0
    totals["messages"] = 0
    per_tenant: Dict[str, dict] = {}
    epoch_rows: List[dict] = []
    clean_p99: Dict[str, float] = {}
    chaos_p99: Dict[str, float] = {}
    builds0 = fabric.program_builds
    tenant_of_group: Dict[int, str] = {}
    for e, (sc, tenant_of_group) in enumerate(scs):
        ecfg = (replace(cfg, faults=epoch_faults[e])
                if epoch_faults[e] is not None else cfg)
        res = run(sc, ecfg)
        for k in _COUNTERS:
            totals[k] += int(res.get(k, 0))
        totals["unfinished"] += int(res["unfinished"])
        totals["messages"] += len(sc.messages)
        row = {"epoch": e, "max_fct_us": res["max_fct"],
               "unfinished": res["unfinished"], "chaos": chaos_flags[e],
               **{k: int(res.get(k, 0)) for k in _COUNTERS},
               "qdepth_max_pkts": res.get("qdepth_max_pkts", 0)}
        epoch_rows.append(row)
        for g, trow in (res.get("tenant_fct") or {}).items():
            name = tenant_of_group.get(g, str(g))
            agg = per_tenant.setdefault(
                name, {"count": 0, "unfinished": 0, "p99_worst": 0.0,
                       "max": 0.0, "p50_last": float("nan")})
            agg["count"] += trow["count"]
            agg["unfinished"] += trow["unfinished"]
            if trow["p99"] == trow["p99"]:          # not NaN
                agg["p99_worst"] = max(agg["p99_worst"], trow["p99"])
                agg["max"] = max(agg["max"], trow["max"])
                agg["p50_last"] = trow["p50"]
                bucket = chaos_p99 if chaos_flags[e] else clean_p99
                bucket[name] = max(bucket.get(name, 0.0), trow["p99"])
        if registry is not None:
            record_epoch(registry, res, tenant_of_group)
            if chaos is not None:
                registry.declare(
                    "strack_fct_degradation_ratio",
                    "per-tenant worst-p99 FCT, chaos epochs over clean "
                    "epochs (1.0 = no degradation)", "gauge")
                for name in sorted(set(chaos_p99) & set(clean_p99)):
                    base = clean_p99[name]
                    if base > 0:
                        registry.set("strack_fct_degradation_ratio",
                                     chaos_p99[name] / base, tenant=name)
            if out_path:
                from ..obs.metrics import render_prometheus
                with open(out_path, "w") as f:
                    f.write(render_prometheus(registry))
        if verbose:
            print(f"soak[{e + 1}/{epochs}]: max_fct {res['max_fct']:.1f}us"
                  f", drops {row['drops']}, pauses {row['pauses']}, ecn "
                  f"{row['ecn_marks']}, retx {row['retransmits']}, "
                  f"unfinished {res['unfinished']}")
    if chaos is not None:
        for name, agg in per_tenant.items():
            base = clean_p99.get(name, 0.0)
            ch = chaos_p99.get(name, 0.0)
            agg["degradation_p99"] = (ch / base if base > 0 and ch > 0
                                      else float("nan"))
    return {
        "epochs": epochs,
        "n_ticks": int(n_ticks),
        "totals": totals,
        "per_tenant": per_tenant,
        "tenant_of_group": tenant_of_group,
        "epoch_rows": epoch_rows,
        "program_builds": fabric.program_builds - builds0,
    }
