"""Vectorized multi-queue fat-tree fabric — one XLA program, real multipath.

The jitted counterpart of the ``events.py`` oracle: a 2-tier Clos fabric
(host NICs -> per-ToR uplink queues -> per-spine downlink queues -> per-host
downlink queues) simulated as fixed-shape ring-buffer arrays inside a single
``lax.scan``.  Path entropy now *matters* on the fast path: every packet is
ECMP-hashed (the jnp mirror of ``topology._mix``) onto a live uplink of its
source ToR, so the vmapped flow engines in ``core/transport.py`` see
genuinely divergent per-path ECN/RTT signals and Algorithm 2's spray state
steers real queues.

Time model (1 tick = 1 MTU serialization time at link rate):

  * each host clocks out <=1 data packet per tick (NIC rate == link rate;
    flows sharing a NIC are arbitrated round-robin) plus rare probes,
  * every fabric queue serves 1 packet/tick; served packets advance to the
    next hop *this* tick and are eligible for service the next tick, so a
    hop costs >=1 tick of serialization plus any queueing,
  * egress ECN marking on the residual queue depth between Kmin..Kmax
    (deterministic dither), silent tail drop of data beyond 5 BDP,
  * SACKs ride a fixed-latency per-flow return pipe covering the base-RTT
    remainder (propagation + reverse path), as in ``jaxsim.py``.

sim/ module map
---------------
  topology.py  FatTree: Python Clos model + ECMP hash (shared ground truth)
  fabric.py    this file — the fast path; >=4-ToR fabrics, adaptive /
               oblivious / fixed-path spray, dead links, oversubscription
  jaxsim.py    the 1-queue special case of the fabric (incast Figs 16-20)
  events.py    discrete-event oracle — STrack *and* RoCEv2/PFC baselines,
               collective traces; ~1000x slower, used for parity tests
  workloads.py scenario configs (permutation/incast/oversub/linkdown)
               runnable on either backend
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core import reliability as rel
from ..core import transport as tp
from ..core.params import NetworkSpec, STrackParams, make_strack_params
from ..core.reliability import SackMsg
from .topology import FatTree

LB_MODES = ("adaptive", "oblivious", "fixed")


def ecmp_mix(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """jnp mirror of ``topology._mix`` (uint32 wrap-around arithmetic)."""
    u = jnp.uint32
    h = a.astype(jnp.uint32) * u(2654435761)
    h = h ^ (b.astype(jnp.uint32) * u(2246822519))
    h = h * u(3266489917)
    h = h ^ (c.astype(jnp.uint32) * u(668265263))
    h = h * u(374761393)
    return ((h >> u(8)) ^ (h & u(0xFF))).astype(jnp.int32)


class ArrayTopo(NamedTuple):
    """Array-ized FatTree: everything the jitted fabric needs as jnp data."""

    n_tor: int
    n_spine: int
    hosts_per_tor: int
    n_hosts: int
    live_mask: jax.Array   # bool[T, S]: (tor, spine) link is up
    live_list: jax.Array   # i32[T, S]: i-th live spine of tor (padded)
    n_live: jax.Array      # i32[T]

    @classmethod
    def from_fat_tree(cls, topo: FatTree) -> "ArrayTopo":
        T, S = topo.n_tor, topo.n_spine
        mask = [[(t, s) not in topo.dead_links for s in range(S)]
                for t in range(T)]
        llist, nlive = [], []
        for t in range(T):
            ups = topo.live_up[t]
            llist.append(ups + [ups[0]] * (S - len(ups)))
            nlive.append(len(ups))
        return cls(n_tor=T, n_spine=S, hosts_per_tor=topo.hosts_per_tor,
                   n_hosts=topo.n_hosts,
                   live_mask=jnp.asarray(mask, bool),
                   live_list=jnp.asarray(llist, jnp.int32),
                   n_live=jnp.asarray(nlive, jnp.int32))

    def tor_of(self, host: jax.Array) -> jax.Array:
        return host // self.hosts_per_tor

    def ecmp_spine(self, src: jax.Array, dst: jax.Array,
                   entropy: jax.Array) -> jax.Array:
        """Vectorized ECMP onto a live uplink (bit-exact vs FatTree)."""
        tor = self.tor_of(src)
        k = ecmp_mix(src, dst, entropy) % self.n_live[tor]
        return self.live_list[tor, k]


class PktQ(NamedTuple):
    """Ring-buffer packet fields, shape [n_queues + 1, cap] (last row trash)."""

    flow: jax.Array    # i32
    psn: jax.Array     # i32
    ts: jax.Array      # f32 (send timestamp, us)
    probe: jax.Array   # bool
    ecn: jax.Array     # bool (accumulated across hops)
    ent: jax.Array     # i32 (path entropy)


class FabricState(NamedTuple):
    flows: tp.FlowState      # vmapped [N]
    rcv: rel.ReceiverState   # vmapped [N] (one receiver context per flow)
    q: PktQ                  # [Q+1, cap]
    qhead: jax.Array         # i32[Q+1]
    qsize: jax.Array         # i32[Q+1]
    pipe: SackMsg            # [H, N]: per-flow SACK return pipe
    obl_rr: jax.Array        # i32[N]: oblivious-spray round robin
    drops: jax.Array         # i32
    delivered: jax.Array     # f32[N]
    done_tick: jax.Array     # i32[N], -1 until message completion


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    net: NetworkSpec = dataclasses.field(default_factory=NetworkSpec)
    max_paths: int = 64
    lb_mode: str = "adaptive"        # adaptive | oblivious | fixed
    timer_every: int = 8             # ticks between timer sweeps
    delay_ticks: Optional[int] = None  # return-pipe latency override


def _empty_sack_pipe(p: STrackParams, h: int, n: int) -> SackMsg:
    z = lambda dt: jnp.zeros((h, n), dt)
    return SackMsg(valid=z(bool), epsn=z(jnp.int32), sack_base=z(jnp.int32),
                   sack_bits=jnp.zeros((h, n, p.sack_bitmap_bits), bool),
                   bytes_recvd=z(jnp.float32), ooo_cnt=z(jnp.int32),
                   ecn=z(bool), entropy=z(jnp.int32), ts=z(jnp.float32),
                   probe_reply=z(bool))


def _bwhere(mask, new, old):
    """tree-where with a leading mask broadcast over trailing dims."""
    return jax.tree.map(
        lambda n, o: jnp.where(
            mask.reshape(mask.shape + (1,) * (n.ndim - mask.ndim)), n, o),
        new, old)


def _scatter_rows(tree_all, tree_rows, idx, n):
    """Scatter rows into per-flow pytrees; idx == n hits a trash row."""
    def one(a, b):
        pad = jnp.zeros((1,) + a.shape[1:], a.dtype)
        return jnp.concatenate([a, pad], 0).at[idx].set(b)[:n]
    return jax.tree.map(one, tree_all, tree_rows)


def _scatter_add(vec, idx, val, n):
    pad = jnp.zeros((1,) + vec.shape[1:], vec.dtype)
    return jnp.concatenate([vec, pad], 0).at[idx].add(val)[:n]


def run_fabric(topo: FatTree,
               flows: Sequence[Tuple[int, int, float]],
               n_ticks: int,
               cfg: FabricConfig = FabricConfig()):
    """Simulate ``flows`` = [(src_host, dst_host, msg_bytes), ...] on a
    fat-tree for ``n_ticks``; returns (final_state, per-tick metrics)."""
    assert cfg.lb_mode in LB_MODES, cfg.lb_mode
    net = cfg.net
    p = make_strack_params(net, max_paths=cfg.max_paths)
    at = ArrayTopo.from_fat_tree(topo)
    T, S, NH = at.n_tor, at.n_spine, at.n_hosts
    TS = T * S
    Q = 2 * TS + NH                     # tor_up + spine_down + host_down
    N = len(flows)
    assert N > 0

    tick_us = net.mtu_serialize_us
    kmin_p = net.ecn_kmin_bytes / net.mtu_bytes
    kmax_p = net.ecn_kmax_bytes / net.mtu_bytes
    drop_pkts = int(net.drop_bytes // net.mtu_bytes)
    # worst-case same-tick arrivals at one queue: every ToR host injecting
    # data+probe (tor_up / host_down) or every spine/ToR handing down a pkt
    max_extra = max(T, S + 2 * at.hosts_per_tor)
    hard_pkts = drop_pkts + max_extra   # probes squeeze past the data drop
    cap = hard_pkts + max_extra + 2
    if cfg.delay_ticks is not None:
        D = int(cfg.delay_ticks)
    else:
        D = max(1, round(net.base_rtt_us / tick_us) - 3)
    H = D + 2

    src = jnp.asarray([f[0] for f in flows], jnp.int32)
    dst = jnp.asarray([f[1] for f in flows], jnp.int32)
    for s_, d_ in [(f[0], f[1]) for f in flows]:
        assert 0 <= s_ < NH and 0 <= d_ < NH and s_ != d_, (s_, d_)
    total_pkts = jnp.asarray(
        [int(math.ceil(f[2] / net.mtu_bytes)) for f in flows], jnp.int32)
    src_tor = src // at.hosts_per_tor
    dst_tor = dst // at.hosts_per_tor
    same_tor = src_tor == dst_tor
    iota_n = jnp.arange(N, dtype=jnp.int32)
    fixed_ent = ecmp_mix(src, dst, iota_n) % p.max_paths
    mtu_f = jnp.float32(net.mtu_bytes)

    fl0 = jax.vmap(lambda tpk: tp.init_flow(p, tpk))(total_pkts)
    rcv0 = jax.vmap(rel.init_receiver)(total_pkts)
    q0 = PktQ(flow=jnp.full((Q + 1, cap), -1, jnp.int32),
              psn=jnp.zeros((Q + 1, cap), jnp.int32),
              ts=jnp.zeros((Q + 1, cap), jnp.float32),
              probe=jnp.zeros((Q + 1, cap), bool),
              ecn=jnp.zeros((Q + 1, cap), bool),
              ent=jnp.zeros((Q + 1, cap), jnp.int32))
    st0 = FabricState(
        flows=fl0, rcv=rcv0, q=q0,
        qhead=jnp.zeros((Q + 1,), jnp.int32),
        qsize=jnp.zeros((Q + 1,), jnp.int32),
        pipe=_empty_sack_pipe(p, H, N),
        obl_rr=iota_n % p.max_paths,   # stagger oblivious spray starts
        drops=jnp.zeros((), jnp.int32),
        delivered=jnp.zeros((N,), jnp.float32),
        done_tick=jnp.full((N,), -1, jnp.int32))

    qrows = jnp.arange(Q, dtype=jnp.int32)
    is_up_row = qrows < TS
    spine_of_row = jnp.where(is_up_row, qrows % S, (qrows - TS) // T)

    def tick_fn(st: FabricState, t):
        now = t.astype(jnp.float32) * tick_us

        # ---- 1. serve: every queue pops its head packet ------------------
        qs = st.qsize[:Q]
        has = qs > 0
        hidx = st.qhead[:Q] % cap
        pop = PktQ(*[f[qrows, hidx] for f in st.q])
        residual = jnp.maximum(qs - 1, 0).astype(jnp.float32)
        frac = jnp.clip((residual - kmin_p)
                        / jnp.maximum(kmax_p - kmin_p, 1e-9), 0.0, 1.0)
        dither = jnp.abs(jnp.sin(t.astype(jnp.float32) * 12.9898
                                 + qrows.astype(jnp.float32) * 78.233))
        mark = has & (~pop.probe) & (frac > dither * 0.999)
        ecn_out = pop.ecn | mark
        served = has.astype(jnp.int32)
        qhead = st.qhead.at[:Q].add(served)
        qsize = st.qsize.at[:Q].add(-served)

        fclip = jnp.clip(pop.flow, 0, N - 1)
        # fabric advance targets (tor_up -> spine_down -> host_down)
        adv_tgt = jnp.where(
            is_up_row, TS + spine_of_row * T + dst_tor[fclip],
            2 * TS + dst[fclip])[:2 * TS]
        adv_valid = has[:2 * TS]
        adv = PktQ(flow=pop.flow[:2 * TS], psn=pop.psn[:2 * TS],
                   ts=pop.ts[:2 * TS], probe=pop.probe[:2 * TS],
                   ecn=ecn_out[:2 * TS], ent=pop.ent[:2 * TS])

        # ---- 2. deliveries -> per-flow receivers (one host = one queue) --
        del_has = has[2 * TS:]
        del_flow = fclip[2 * TS:]
        rrows = jax.tree.map(lambda a: a[del_flow], st.rcv)
        rnew, sack = jax.vmap(
            lambda r, psn, ecn, ent, ts, pb: rel.receiver_on_data(
                r, p, psn, mtu_f, ecn, ent, ts, pb))(
            rrows, pop.psn[2 * TS:], ecn_out[2 * TS:], pop.ent[2 * TS:],
            pop.ts[2 * TS:], pop.probe[2 * TS:])
        rnew = _bwhere(del_has, rnew, rrows)
        rcv = _scatter_rows(st.rcv, rnew,
                            jnp.where(del_has, del_flow, N), N)
        delivered = _scatter_add(
            st.delivered,
            jnp.where(del_has & (~pop.probe[2 * TS:]), del_flow, N),
            mtu_f, N)

        # write emitted SACKs into the return pipe, slot t + D
        sack_valid = sack.valid & del_has
        wslot = (t + D) % H
        prow = jax.tree.map(lambda a: a[wslot], st.pipe)
        prow = _scatter_rows(prow, sack._replace(valid=sack_valid),
                             jnp.where(sack_valid, del_flow, N), N)
        pipe = jax.tree.map(lambda a, r: a.at[wslot].set(r), st.pipe, prow)

        # ---- 3. due SACKs reach their senders ----------------------------
        cur = t % H
        due = jax.tree.map(lambda a: a[cur], pipe)
        flows = jax.vmap(lambda f, s_: tp.flow_on_sack(f, p, s_, now))(
            st.flows, due)
        pipe = pipe._replace(
            valid=pipe.valid.at[cur].set(jnp.zeros((N,), bool)))

        # ---- 4. timers (probes / RTO) every timer_every ticks ------------
        def timers(fl):
            return jax.vmap(lambda f: tp.flow_on_timer(f, p, now))(fl)

        empty_tx = tp.TxPacket(
            valid=jnp.zeros((N,), bool), psn=jnp.zeros((N,), jnp.int32),
            entropy=jnp.zeros((N,), jnp.int32),
            is_rtx=jnp.zeros((N,), bool), is_probe=jnp.zeros((N,), bool))
        flows, probe_tx = jax.lax.cond(
            (t % cfg.timer_every) == 0, timers,
            lambda fl: (fl, empty_tx), flows)

        # ---- 5. sends: each NIC clocks out <=1 data pkt (RR arbitration) -
        flows_sent, tx = jax.vmap(
            lambda f: tp.flow_next_packet(f, p, now))(flows)
        score = jnp.where(tx.valid, (iota_n - t) % N, N)
        best = jax.ops.segment_min(score, src, num_segments=NH)
        sel = tx.valid & (score == best[src])
        flows = _bwhere(sel, flows_sent, flows)

        if cfg.lb_mode == "adaptive":
            ent = tx.entropy
            ent_probe = probe_tx.entropy
            obl_rr = st.obl_rr
        elif cfg.lb_mode == "oblivious":
            ent = (st.obl_rr + 1) % p.max_paths
            ent_probe = ent
            obl_rr = jnp.where(sel, ent, st.obl_rr)
        else:  # fixed: single-path pinning baseline
            ent = fixed_ent
            ent_probe = fixed_ent
            obl_rr = st.obl_rr

        spine = at.ecmp_spine(src, dst, ent)
        inj_q = jnp.where(same_tor, 2 * TS + dst, src_tor * S + spine)
        spine_p = at.ecmp_spine(src, dst, ent_probe)
        inj_qp = jnp.where(same_tor, 2 * TS + dst, src_tor * S + spine_p)

        # ---- 6. enqueue: fabric advances + data + probes -----------------
        cand_qid = jnp.concatenate([adv_tgt, inj_q, inj_qp])
        cand_valid = jnp.concatenate([adv_valid, sel, probe_tx.valid])
        now_n = jnp.full((N,), now, jnp.float32)
        zb, ob = jnp.zeros((N,), bool), jnp.ones((N,), bool)
        cand = PktQ(
            flow=jnp.concatenate([adv.flow, iota_n, iota_n]),
            psn=jnp.concatenate([adv.psn, tx.psn, probe_tx.psn]),
            ts=jnp.concatenate([adv.ts, now_n, now_n]),
            probe=jnp.concatenate([adv.probe, zb, ob]),
            ecn=jnp.concatenate([adv.ecn, zb, zb]),
            ent=jnp.concatenate([adv.ent, ent, ent_probe]))
        M = 2 * TS + 2 * N
        # Two-pass enqueue. Pass 1: drop decision from the occupancy bound
        # qsize + rank-among-valid (over-counts same-tick earlier drops by
        # design — the queue is at threshold then anyway).  Pass 2: ring
        # positions from rank-among-ACCEPTED, so accepted packets pack the
        # ring contiguously and a drop never leaves a stale gap slot.
        tril = jnp.tril(jnp.ones((M, M), bool), k=-1)
        same_q = cand_qid[:, None] == cand_qid[None, :]
        rank_v = jnp.sum(same_q & cand_valid[None, :] & tril,
                         axis=1).astype(jnp.int32)
        occ = qsize[cand_qid] + rank_v
        dropped = cand_valid & (((~cand.probe) & (occ >= drop_pkts))
                                | (occ >= hard_pkts))
        accept = cand_valid & (~dropped)
        rank_a = jnp.sum(same_q & accept[None, :] & tril,
                         axis=1).astype(jnp.int32)
        pos = (qhead[cand_qid] + qsize[cand_qid] + rank_a) % cap
        flat_idx = jnp.where(accept, cand_qid * cap + pos, Q * cap)
        q = PktQ(*[f.reshape(-1).at[flat_idx].set(v).reshape(Q + 1, cap)
                   for f, v in zip(st.q, cand)])
        added = jax.ops.segment_sum(
            accept.astype(jnp.int32),
            jnp.where(accept, cand_qid, Q), num_segments=Q + 1)
        qsize = (qsize + added).at[Q].set(0)
        qhead = qhead.at[Q].set(0)
        drops = st.drops + jnp.sum(dropped).astype(jnp.int32)

        # ---- 7. completion + metrics ------------------------------------
        done = jax.vmap(tp.flow_done)(flows)
        done_tick = jnp.where(done & (st.done_tick < 0),
                              t.astype(jnp.int32), st.done_tick)

        new_st = FabricState(flows=flows, rcv=rcv, q=q, qhead=qhead,
                             qsize=qsize, pipe=pipe, obl_rr=obl_rr,
                             drops=drops, delivered=delivered,
                             done_tick=done_tick)
        metrics = {
            "qsize": qsize[:Q],
            "drops": drops,
            "done": jnp.sum(done).astype(jnp.int32),
            "cwnd_mean": jnp.mean(flows.cc.cwnd),
            "delivered": delivered,
        }
        return new_st, metrics

    @jax.jit
    def run(st):
        return jax.lax.scan(tick_fn, st,
                            jnp.arange(n_ticks, dtype=jnp.int32))

    final, metrics = run(st0)
    done_tick = jax.device_get(final.done_tick)
    metrics["tick_us"] = tick_us
    metrics["target_qdelay_pkts"] = p.target_qdelay_us / tick_us
    metrics["done_tick"] = done_tick
    # +1: a message is complete when its last SACK lands, i.e. at tick end
    metrics["fct_us"] = [
        float((dt + 1) * tick_us) if dt >= 0 else None for dt in done_tick]
    metrics["queue_ids"] = {
        "tor_up": lambda t_, s_: t_ * S + s_,
        "spine_down": lambda s_, t_: TS + s_ * T + t_,
        "host_down": lambda h_: 2 * TS + h_,
    }
    return final, metrics


def summarize(metrics: dict) -> dict:
    """Event-oracle-style summary (max/avg FCT, unfinished, drops)."""
    import numpy as np
    fcts = [f for f in metrics["fct_us"] if f is not None]
    return {
        "max_fct": max(fcts) if fcts else float("nan"),
        "avg_fct": sum(fcts) / len(fcts) if fcts else float("nan"),
        "unfinished": sum(1 for f in metrics["fct_us"] if f is None),
        "drops": int(np.asarray(metrics["drops"])[-1]),
        "pauses": 0,   # the fabric is lossy-only (no PFC)
    }
