"""Vectorized multi-queue fat-tree fabric — one XLA program, every protocol.

The jitted counterpart of the ``events.py`` oracle: a 2-tier Clos fabric
(host NICs -> per-ToR uplink queues -> per-spine downlink queues -> per-host
downlink queues) simulated as fixed-shape ring-buffer arrays inside a single
``lax.scan``.  The fabric is *protocol-generic*: per-flow transport logic is
plugged in through a :class:`Protocol` record of init / on-data / on-ack /
on-timer / next-packet transition functions, and both of the paper's
transports run on this fast path:

  * **STrack** (``core/transport.py``): window-based CC + adaptive spray +
    selective retransmission.  Path entropy matters: every packet is
    ECMP-hashed (the jnp mirror of ``topology._mix``) onto a live uplink,
    so Algorithm 2's spray state steers real queues.
  * **RoCEv2** (``dcqcn_fab.py``): DCQCN rate-based CC + go-back-N, single
    fixed path per flow — the paper's baseline, previously event-sim-only.

The queue layer also models **PFC** (priority flow control) for lossless
mode: per-ingress byte accounting against the dynamic shared-buffer
threshold ``xoff = alpha * free / (1 + alpha)`` (mirroring
``events.Switch``), with pause/resume masks applied inside the scan —
a paused fabric queue stops serving, a paused NIC stops injecting.

The scan is **event-horizon driven** by default at the experiment API
(``FabricConfig.time_warp``): after any tick that leaves the fabric
provably idle — no queued packet, no released flow offering a packet, no
unrecorded dependency release — the loop advances ``now`` straight to the
earliest next interesting time (pending timer expiry via
``Protocol.next_event``, pacing/rate credit release, or return-pipe
arrival) in one trip, so dependency stalls, DCQCN recovery backoff and
post-completion tails cost O(1) instead of one trip per dead tick.
Completion ticks, drops and pause counts are bit-identical to dense
ticking (tests/test_timewarp.py); the per-tick metrics trace is opt-in
and decimated (``trace_every``) since a data-dependent trip count cannot
stack one.  Programs are built+jitted once per static shape through an
LRU cache (``_get_program``), with ``lb_mode`` a traced scalar so spray
modes, entropy seeds and message patterns all reuse one XLA program —
``workloads.sweep()`` vmaps those axes through it.  docs/performance.md
has the full model and the ``make bench`` numbers.

Time model (1 tick = 1 MTU serialization time at link rate), since the
per-hop latency pipeline (``ack_path="perhop"``, the default):

  * each host clocks out <=1 data packet per tick (NIC rate == link rate;
    flows sharing a NIC are arbitrated round-robin) plus rare probes,
  * every queue-ring slot carries a *departure-time lane* (``PktQ.ready``):
    a packet served or injected at tick ``t`` becomes serviceable at the
    next hop at ``t + 1 + hop_prop_ticks`` — one tick of serialization
    plus the per-link propagation delay, both accrued AT EVERY TRAVERSED
    STAGE (host->uplink->downlink->host), so RTT samples and ECN marks
    reflect real per-hop queueing + propagation instead of one folded
    constant,
  * egress ECN marking on the residual queue depth between Kmin..Kmax
    (deterministic dither; RoCEv2 mode uses the 1-BDP DCQCN threshold),
  * lossy mode tail-drops data beyond 5 BDP; lossless (PFC) mode never
    drops data — backpressure bounds the queues; PFC accounting is
    per-PACKET wire bytes (odd tails and 64B probes, not whole MTUs) and
    pause/resume frames take ``pfc_delay_ticks`` to reach the upstream
    queue (one hop of propagation, as in the oracle),
  * ACK/SACK/CNP messages return through a per-flow reverse-path pipe
    whose latency is the ACK's own store-and-forward pipeline —
    ``hops * (prop + ack serialization)`` for that flow's path (2 hops
    same-ToR, 4 cross-ToR) — so the uncongested data+ACK round trip
    realizes exactly ``net.base_rtt_us`` on fabric AND oracle,
  * variable message sizes are first-class: the final PSN of a message is
    its odd tail (``ref.pkt_size`` semantics) in the send window, DCQCN
    pacing/byte-counter, receiver byte counts and PFC accounting; a tail
    packet still costs one serialization tick (tick quantization).
  * ``ack_path="folded"`` (or a ``delay_ticks`` override, as ``jaxsim.py``
    uses) restores the legacy model: no per-hop propagation, the full
    base-RTT remainder folded into one fixed-latency return pipe.

Dependency-scheduled messages (collective traces, Figs 21-28) run inside
the same ``lax.scan``: every flow belongs to a *message*, messages carry
static dependency edges, and per-message pending-dep counters gate sending —
a message becomes sendable the tick its counter reaches zero, and its
completion decrements its children's counters.  Messages optionally fan out
into ``subflows`` striped sub-flows (the paper's 4-QP "optimized RoCEv2"),
each a single-path flow with its own entropy; the message completes when the
last sub-flow completes.  Plain flow lists are the deps-free, 1-sub-flow
special case of the same machinery.

sim/ module map
---------------
  topology.py   FatTree: Python Clos model + ECMP hash (shared ground truth)
  fabric.py     this file — the fast path for BOTH protocols; >=4-ToR
                fabrics, spray modes, dead links, oversubscription, PFC,
                dependency gating + sub-flow striping for collective traces
  dcqcn_fab.py  RoCEv2 (DCQCN + go-back-N) per-flow transitions
  jaxsim.py     the 1-queue special case of the fabric (incast Figs 16-20)
  events.py     discrete-event oracle (parity tests + TraceRunner oracle
                for the collective parity gates); ~1000x slower
  workloads.py  the one experiment API: Scenario (dependency-edged
                messages) + RunConfig + run()/sweep() over both backends
"""
from __future__ import annotations

import dataclasses
import math
import random
from collections import OrderedDict
from typing import Callable, NamedTuple, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from .. import compat
from ..core import reliability as rel
from ..kernels.fabric_kernels import (flow_transition_kernel, iota1,
                                      rank_in_queue_core,
                                      serve_enqueue_kernel)
from ..core import transport as tp
from ..core.params import (ACK_WIRE_BYTES, NetworkSpec, RoCEParams,
                           STrackParams, make_roce_params,
                           make_strack_params)
from ..core.reliability import SackMsg
from .faults import (FaultSpec, build_fault_data, duty_open, fault_u01,
                     validate_faults)
from .dcqcn_fab import (RoceFabParams, empty_roce_msgs, init_roce_flow,
                        init_roce_rcv, make_roce_fab_params, roce_done,
                        roce_next_event, roce_next_packet, roce_on_ack,
                        roce_on_data, roce_on_timer)
from .topology import FatTree

LB_MODES = ("adaptive", "oblivious", "fixed")
PROTOCOLS = ("strack", "rocev2")
ACK_PATHS = ("perhop", "folded")
KERNEL_BACKENDS = ("jnp", "pallas", "pallas_interpret")


def ecmp_mix(a: jax.Array, b: jax.Array, c: jax.Array) -> jax.Array:
    """jnp mirror of ``topology._mix`` (uint32 wrap-around arithmetic)."""
    u = jnp.uint32
    h = a.astype(jnp.uint32) * u(2654435761)
    h = h ^ (b.astype(jnp.uint32) * u(2246822519))
    h = h * u(3266489917)
    h = h ^ (c.astype(jnp.uint32) * u(668265263))
    h = h * u(374761393)
    return ((h >> u(8)) ^ (h & u(0xFF))).astype(jnp.int32)


class ArrayTopo(NamedTuple):
    """Array-ized FatTree: everything the jitted fabric needs as jnp data."""

    n_tor: int
    n_spine: int
    hosts_per_tor: int
    n_hosts: int
    live_mask: jax.Array   # bool[T, S]: (tor, spine) link is up
    live_list: jax.Array   # i32[T, S]: i-th live spine of tor (padded)
    n_live: jax.Array      # i32[T]

    @classmethod
    def from_fat_tree(cls, topo: FatTree) -> "ArrayTopo":
        T, S = topo.n_tor, topo.n_spine
        mask = [[(t, s) not in topo.dead_links for s in range(S)]
                for t in range(T)]
        llist, nlive = [], []
        for t in range(T):
            ups = topo.live_up[t]
            llist.append(ups + [ups[0]] * (S - len(ups)))
            nlive.append(len(ups))
        return cls(n_tor=T, n_spine=S, hosts_per_tor=topo.hosts_per_tor,
                   n_hosts=topo.n_hosts,
                   live_mask=jnp.asarray(mask, bool),
                   live_list=jnp.asarray(llist, jnp.int32),
                   n_live=jnp.asarray(nlive, jnp.int32))

    def tor_of(self, host: jax.Array) -> jax.Array:
        return host // self.hosts_per_tor

    def ecmp_spine(self, src: jax.Array, dst: jax.Array,
                   entropy: jax.Array) -> jax.Array:
        """Vectorized ECMP onto a live uplink (bit-exact vs FatTree)."""
        tor = self.tor_of(src)
        k = ecmp_mix(src, dst, entropy) % self.n_live[tor]
        return self.live_list[tor, k]


# --------------------------------------------------------------------------- #
# Protocol dispatch: the per-flow transport plugged into the fabric
# --------------------------------------------------------------------------- #

class Protocol(NamedTuple):
    """Per-flow transport engine record (all fns are per-flow; the fabric
    vmaps them).  Message pytrees must carry a bool ``valid`` leaf named
    ``valid`` — the return pipe relies on it.

      init(total_pkts[N], tail_bytes[N], entropy0[N])
                                       -> (flow_states, rcv_states)
      empty_msgs(h, n)                 -> msg pytree, leading dims (h, n)
      on_data(rcv, psn, size, ecn, ent, ts, probe, now) -> (rcv, msg)
      on_ack(flow, msg, now)           -> flow
      on_timer(flow, now)              -> (flow, TxPacket)
      next_packet(flow, now)           -> (flow, TxPacket)
      done(flow)                       -> bool
      cong_pkts(flow)                  -> f32 window-equivalent in packets
      next_event(flow)                 -> (timer_event_us, send_event_us):
          the earliest future times at which on_timer / next_packet stop
          being no-ops for this flow (+inf if never) — the per-flow half
          of the event-horizon (time-warp) scan contract: before those
          times, an idle fabric can skip ticks without changing state.
      stat_retx(flows)                 -> i32 per-flow retransmitted-packet
          count, derived elementwise from the final flow pytree (works on
          vmapped [B, N] states too) — observability only, never read
          inside the scan.
      stat_recovery(flows)             -> dict of i32 per-flow recovery
          counters with the UNIFORM keys ``rto_fires`` /
          ``sack_recoveries`` / ``gbn_rewinds`` — zero-filled where a
          protocol has no such mechanism, so summaries and dashboards
          never KeyError across protocols.
    """

    name: str
    uses_spray: bool       # fabric lb_mode applies; else protocol's entropy
    init: Callable
    empty_msgs: Callable
    on_data: Callable
    on_ack: Callable
    on_timer: Callable
    next_packet: Callable
    done: Callable
    cong_pkts: Callable
    next_event: Callable
    stat_retx: Callable
    stat_recovery: Callable


def _empty_sack_pipe(p: STrackParams, h: int, n: int) -> SackMsg:
    z = lambda dt: jnp.zeros((h, n), dt)
    return SackMsg(valid=z(bool), epsn=z(jnp.int32), sack_base=z(jnp.int32),
                   sack_bits=jnp.zeros((h, n, p.sack_bitmap_bits), bool),
                   bytes_recvd=z(jnp.float32), ooo_cnt=z(jnp.int32),
                   ecn=z(bool), entropy=z(jnp.int32), ts=z(jnp.float32),
                   probe_reply=z(bool))


def make_strack_protocol(p: STrackParams) -> Protocol:
    """STrack: window CC (Algo 3/4) + spray (Algo 2) + SACK reliability."""

    def init(total_pkts, tail_bytes, entropy0):
        del entropy0  # spray picks paths; no per-flow pinned entropy
        fl = jax.vmap(lambda tpk, tb: tp.init_flow(p, tpk, tail_bytes=tb))(
            total_pkts, tail_bytes)
        rcv = jax.vmap(rel.init_receiver)(total_pkts)
        return fl, rcv

    def on_data(r, psn, size, ecn, ent, ts, probe, now):
        del now
        return rel.receiver_on_data(r, p, psn, size, ecn, ent, ts, probe)

    def on_timer(f, now):
        # The oracle only arms a flow's timers when the flow is added
        # (i.e. when its dependencies released it); mirror that by holding
        # probes until the flow has actually sent data.
        f2, tx = tp.flow_on_timer(f, p, now)
        started = f.rel.bytes_sent > 0
        probe = tx.valid & started
        return f2, tx._replace(valid=probe, is_probe=probe)

    def stat_retx(f):
        # STrack tracks cumulative bytes_sent (first transmissions +
        # retransmissions); the excess over the message's wire bytes,
        # rounded to MTUs, is the retransmitted-packet count.
        wire = ((f.rel.total_pkts - 1).astype(jnp.float32) * p.mtu_bytes
                + f.rel.tail_bytes)
        extra = jnp.round((f.rel.bytes_sent - wire) / p.mtu_bytes)
        return jnp.where(f.rel.total_pkts > 0,
                         jnp.maximum(extra, 0.0).astype(jnp.int32), 0)

    return Protocol(
        name="strack", uses_spray=True, init=init,
        empty_msgs=lambda h, n: _empty_sack_pipe(p, h, n),
        on_data=on_data,
        on_ack=lambda f, m, now: tp.flow_on_sack(f, p, m, now),
        on_timer=on_timer,
        next_packet=lambda f, now: tp.flow_next_packet(f, p, now),
        done=tp.flow_done,
        cong_pkts=lambda f: f.cc.cwnd,
        next_event=lambda f: tp.flow_next_event(f, p),
        stat_retx=stat_retx,
        stat_recovery=lambda f: {
            "rto_fires": f.rel.rto_fires,
            "sack_recoveries": f.rel.recoveries,
            "gbn_rewinds": jnp.zeros_like(f.rel.rto_fires)})


def make_rocev2_protocol(p: RoceFabParams) -> Protocol:
    """RoCEv2: DCQCN rate CC + go-back-N, one fixed path per flow."""

    def init(total_pkts, tail_bytes, entropy0):
        fl = jax.vmap(lambda tpk, e, tb: init_roce_flow(
            p, tpk, e, tail_bytes=tb))(total_pkts, entropy0, tail_bytes)
        rcv = jax.vmap(init_roce_rcv)(total_pkts)
        return fl, rcv

    def on_data(r, psn, size, ecn, ent, ts, probe, now):
        del ent, ts, probe  # single path; RTT is not a DCQCN signal
        return roce_on_data(r, p, psn, size, ecn, now)

    def next_packet(f, now):
        f2, (valid, psn, entropy, is_rtx) = roce_next_packet(f, p, now)
        return f2, tp.TxPacket(valid=valid, psn=psn, entropy=entropy,
                               is_rtx=is_rtx, is_probe=jnp.zeros((), bool))

    def on_timer(f, now):
        f2, probe = roce_on_timer(f, p, now)
        z = jnp.zeros((), jnp.int32)
        return f2, tp.TxPacket(valid=probe, psn=z, entropy=f.entropy,
                               is_rtx=jnp.zeros((), bool), is_probe=probe)

    # window-equivalent in packets: instantaneous rate x base-ish RTT
    rtt_us = p.window_pkts * p.mtu_bytes / p.line_rate_Bpus

    return Protocol(
        name="rocev2", uses_spray=False, init=init,
        empty_msgs=empty_roce_msgs,
        on_data=on_data,
        on_ack=lambda f, m, now: jax.tree.map(
            lambda n_, o: jnp.where(m.valid, n_, o),
            roce_on_ack(f, p, m, now), f),
        on_timer=on_timer,
        next_packet=next_packet,
        done=roce_done,
        cong_pkts=lambda f: f.rate * rtt_us / p.mtu_bytes,
        next_event=lambda f: roce_next_event(f, p),
        stat_retx=lambda f: f.retransmits,
        stat_recovery=lambda f: {
            "rto_fires": f.rto_fires,
            "sack_recoveries": jnp.zeros_like(f.rto_fires),
            "gbn_rewinds": f.gbn_rewinds})


# --------------------------------------------------------------------------- #
# PFC: dynamic-threshold pause/resume gate (shared with the unit tests)
# --------------------------------------------------------------------------- #

def pfc_gate(paused: jax.Array, ingress_bytes: jax.Array,
             xoff_bytes: jax.Array, xon_frac: float = 0.5) -> jax.Array:
    """One PFC hysteresis step, elementwise over ingress ports.

    Pause when the port's accounted bytes exceed ``xoff``; once paused, stay
    paused until they fall below ``xon_frac * xoff`` (``events.Switch``
    semantics: pause > _xoff(), resume < 0.5 * _xoff()).
    """
    pause = ingress_bytes > xoff_bytes
    resume = ingress_bytes < xon_frac * xoff_bytes
    return pause | (paused & ~resume)


# --------------------------------------------------------------------------- #
# Messages: dependency structure + sub-flow striping (static per program)
# --------------------------------------------------------------------------- #

class _FlowMsg(NamedTuple):
    """Minimal message record for the deps-free ``run_fabric`` wrapper
    (``workloads.Message`` is the duck-typed public equivalent)."""

    mid: int
    src: int
    dst: int
    size: float
    deps: tuple = ()
    group: int = 0
    arrival: int = 0


class DepSpec(NamedTuple):
    """Static message/dependency structure a fabric program closes over.

    Flows are the striped sub-flows of messages: ``msg_of_flow`` maps each
    sub-flow back to its message; ``edge_parent[e] -> edge_child[e]`` are
    the dependency edges (child waits for parent); ``init_pending`` is each
    message's dependency in-degree.  ``msg_ids`` / ``group_ids`` keep the
    caller's original identifiers for reporting.
    """

    n_msgs: int
    n_groups: int
    msg_of_flow: jax.Array   # i32[N]
    group_of_msg: jax.Array  # i32[n_msgs]
    init_pending: jax.Array  # i32[n_msgs]
    edge_parent: jax.Array   # i32[E]
    edge_child: jax.Array    # i32[E]
    msg_ids: tuple           # original mids, program order
    group_ids: tuple         # original group ids, program order


def expand_messages(messages, subflows: int = 1):
    """Fan messages out into striped sub-flows.

    Returns ``(flows, dep)`` where ``flows`` is the [(src, dst, bytes), ...]
    list of sub-flows (each message split into ``subflows`` equal stripes,
    mirroring the oracle's multi-QP striping) and ``dep`` the
    :class:`DepSpec` tying them back together.
    """
    k = max(1, int(subflows))
    messages = list(messages)
    if not messages:
        raise ValueError("expand_messages() needs at least one message")
    mid_ix = {m.mid: i for i, m in enumerate(messages)}
    if len(mid_ix) != len(messages):
        raise ValueError("duplicate message ids in trace")
    group_ids = tuple(sorted({m.group for m in messages}))
    gid_ix = {g: i for i, g in enumerate(group_ids)}
    flows, msg_of_flow = [], []
    edge_parent, edge_child, pending = [], [], []
    for i, m in enumerate(messages):
        pending.append(len(m.deps))
        for d in m.deps:
            if d not in mid_ix:
                raise ValueError(f"message {m.mid} depends on unknown "
                                 f"message {d}")
            edge_parent.append(mid_ix[d])
            edge_child.append(i)
        for _ in range(k):
            flows.append((m.src, m.dst, m.size / k))
            msg_of_flow.append(i)
    return flows, DepSpec(
        n_msgs=len(messages), n_groups=len(group_ids),
        msg_of_flow=jnp.asarray(msg_of_flow, jnp.int32),
        group_of_msg=jnp.asarray([gid_ix[m.group] for m in messages],
                                 jnp.int32),
        init_pending=jnp.asarray(pending, jnp.int32),
        edge_parent=jnp.asarray(edge_parent, jnp.int32),
        edge_child=jnp.asarray(edge_child, jnp.int32),
        msg_ids=tuple(m.mid for m in messages),
        group_ids=group_ids)


def _trivial_dep(flows) -> DepSpec:
    """Deps-free 1:1 flow<->message mapping (the plain-flow special case)."""
    n = len(flows)
    iota = jnp.arange(n, dtype=jnp.int32)
    e = jnp.zeros((0,), jnp.int32)
    return DepSpec(n_msgs=n, n_groups=1, msg_of_flow=iota,
                   group_of_msg=jnp.zeros((n,), jnp.int32),
                   init_pending=jnp.zeros((n,), jnp.int32),
                   edge_parent=e, edge_child=e,
                   msg_ids=tuple(range(n)), group_ids=(0,))


class PktQ(NamedTuple):
    """Ring-buffer packet fields, shape [n_queues + 1, cap] (last row trash)."""

    flow: jax.Array    # i32
    psn: jax.Array     # i32
    ts: jax.Array      # f32 (send timestamp, us)
    probe: jax.Array   # bool
    ecn: jax.Array     # bool (accumulated across hops)
    ent: jax.Array     # i32 (path entropy)
    ready: jax.Array   # i32 (departure-time lane: earliest service tick —
    #                    arrival at this hop after upstream serialization
    #                    plus the link's propagation delay)
    spine: jax.Array   # i32 (spine chosen at injection; 0 for same-ToR —
    #                    PFC ingress accounting reads it at the host-down
    #                    dequeue instead of re-deriving ECMP, which would
    #                    diverge once fault schedules make masks
    #                    time-varying)


class FabricState(NamedTuple):
    flows: NamedTuple        # protocol flow states, vmapped [N]
    rcv: NamedTuple          # protocol receiver states, vmapped [N]
    q: PktQ                  # [Q+1, cap]
    qhead: jax.Array         # i32[Q+1]
    qsize: jax.Array         # i32[Q+1]
    pipe: NamedTuple         # [H, N]: per-flow ACK/SACK/CNP return pipe
    obl_rr: jax.Array        # i32[N]: oblivious-spray round robin
    drops: jax.Array         # i32
    delivered: jax.Array     # f32[N]
    done_tick: jax.Array     # i32[N], -1 until message completion
    # --- PFC (all-zero and untouched when pfc is off) ---
    qbytes: jax.Array        # f32[Q+1]: per-queue wire-byte occupancy
    ing_host: jax.Array      # f32[NH]: bytes at ToR(h) from host h's NIC
    ing_sd: jax.Array        # f32[S, T]: bytes at ToR t from spine s
    ing_up: jax.Array        # f32[T, S]: bytes at spine s from ToR t
    paused_nic: jax.Array    # bool[NH]
    paused_sd: jax.Array     # bool[S, T]: spine_down[s][t] paused by ToR t
    paused_up: jax.Array     # bool[T, S]: tor_up[t][s] paused by spine s
    pfc_line: jax.Array      # bool[max(PD,1), NH+2*TS]: pause-frame delay
    #                          line (decision at tick u lands at u + PD)
    pauses: jax.Array        # i32: cumulative pause (xoff) events
    # --- dependency scheduling (trivial when the trace has no deps) ---
    pending: jax.Array           # i32[n_msgs]: unmet dependency count
    msg_done: jax.Array          # bool[n_msgs]
    msg_release_tick: jax.Array  # i32[n_msgs], -1 until sendable
    msg_done_tick: jax.Array     # i32[n_msgs], -1 until complete
    group_done_tick: jax.Array   # i32[G], -1 until all group msgs complete
    act_overflow: jax.Array      # i32: ticks the live-flow count exceeded
    #                              cfg.active_cap (always 0 when unset)
    # --- observability counters (never read back inside the scan) ---
    ecn_marks: jax.Array         # i32: ECN-marked data pkts delivered
    qdepth_hi: jax.Array         # i32[Q+1]: running per-queue depth max
    # --- chaos counters (static zeros when cfg.faults is None) ---
    blackholed: jax.Array        # i32: pkts lost to a down link
    corrupt_drops: jax.Array     # i32: pkts lost to corruption draws
    tx_rows: jax.Array           # i32[Q+1]: accepted data injections per
    #                              target row (entropy-shift observability)
    win_retx: jax.Array          # i32[FW]: retx attempts attributed to
    #                              each flap window (+2 RTO of afterglow)


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    net: NetworkSpec = dataclasses.field(default_factory=NetworkSpec)
    max_paths: int = 64
    lb_mode: str = "adaptive"        # adaptive | oblivious | fixed (STrack)
    timer_every: int = 8             # ticks between timer sweeps
    delay_ticks: Optional[int] = None  # return-pipe latency override
    #                                    (implies the folded legacy model)
    protocol: str = "strack"         # strack | rocev2
    pfc: Optional[bool] = None       # None -> lossless iff rocev2
    # --- per-hop latency pipeline ---------------------------------------
    # "perhop" (default): packets accrue serialization + propagation at
    # every traversed queue stage and ACKs return through a per-flow
    # reverse-path pipe sized to that flow's hop count, so the uncongested
    # RTT realizes net.base_rtt_us exactly (the oracle's model).
    # "folded": the legacy model — no per-hop propagation, the whole
    # base-RTT remainder folded into one fixed return-pipe latency.
    ack_path: str = "perhop"
    # Per-link propagation override (us); None uses the NetworkSpec's
    # derived value (net.hop_prop_effective_us).
    hop_prop_us: Optional[float] = None
    # Ticks a PFC pause/resume frame takes to reach the upstream queue.
    # None derives one hop of propagation (0 in folded mode — the legacy
    # next-tick behavior).
    pfc_delay_ticks: Optional[int] = None
    # Message -> sub-flow striping (paper's 4-QP "optimized RoCEv2"): each
    # message is split into this many equal-size single-QP sub-flows, each
    # with its own path entropy; the message completes when the last
    # sub-flow does.
    subflows: int = 1
    # Shared-buffer bytes per switch for PFC accounting.  NB: the oracle's
    # NetSim default is 64 MB, which never pauses at reduced scale; the
    # fabric default is sized so lossless backpressure is actually exercised
    # (and ring capacity stays bounded).  Parity tests pass the same value
    # to both backends.
    switch_buffer_bytes: float = 4e6
    pfc_alpha: float = 1.0           # dynamic threshold: a * free / (1 + a)
    pfc_xon_frac: float = 0.5        # resume below this fraction of xoff
    roce: Optional[RoCEParams] = None  # rocev2 constant overrides
    # When set, per-flow QP entropy replays ``random.Random(seed)`` in flow
    # order — the exact draw sequence NetSim uses — so a seed-aligned
    # fabric-vs-oracle RoCEv2 run sees identical ECMP collisions.  Default
    # (None) uses a deterministic hash of (src, dst, flow index).
    roce_entropy_seed: Optional[int] = None
    # Event-horizon ("time-warp") scan: when the fabric is provably idle
    # (no queued packets, no sendable packet, no unrecorded dependency
    # release), advance time straight to the earliest next interesting
    # tick — timer sweep, pacing release, or return-pipe arrival — in one
    # scan trip instead of ticking densely through the dead interval.
    # Completion ticks / drops / pauses are bit-identical to dense
    # ticking (tests/test_timewarp.py); only the per-tick trace is
    # unavailable, so time_warp implies trace_every=0.
    time_warp: bool = False
    # Per-tick metrics trace decimation: snapshot the trace every k ticks
    # (1 = dense, the legacy behavior).  0 disables the trace entirely —
    # summaries then come from the final scan carry, which stays exact at
    # any decimation — and is what large-host runs want: the stacked
    # [n_ticks, Q] trace is what used to cap host count.
    trace_every: int = 1
    # Active-set formulation: when set, the per-tick transport work
    # (ACK processing, timers, next-packet, enqueue candidates) runs over
    # at most this many compacted lanes — the flows that are released
    # (deps met) and not yet done — instead of all N flows.  Bit-exact vs
    # the dense formulation as long as the live count never exceeds the
    # cap; an overflow is detected in-scan and raised after the run.
    # Requires trace_every=0 (or time_warp): the decimated trace samples
    # all-flow means that the active set deliberately skips.
    active_cap: Optional[int] = None
    # Shard the fabric over this many devices with shard_map (0/1 = off):
    # queue rings partition by switch row block, flow/receiver/return-pipe
    # state by flow block; popped heads and NIC offers cross pods through
    # explicit all_gather exchanges while all small per-queue vectors stay
    # replicated, so results are bit-exact vs the unsharded program.
    # CPU-only hosts test this via
    # ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    shard: int = 0
    # Kernel backend for the scan body's three hot stages (fused ring
    # service+enqueue, the sort-free enqueue ranker, per-flow protocol
    # transitions — see kernels/fabric_kernels.py):
    #   "jnp"              the stage cores run inline, XLA-fused (default)
    #   "pallas"           compiled Pallas kernels (real TPU/GPU backends)
    #   "pallas_interpret" Pallas interpret mode: the kernel path's call
    #                      structure + bit-exactness on any backend (CPU
    #                      CI; tests/test_fabric_kernels.py)
    # Both Pallas modes are bit-exact vs "jnp" (same stage cores, gated
    # by the differential-fuzz suite).  Single-device only: shard > 1
    # keeps its inline jnp stages (all_gather exchanges cannot live
    # inside a kernel body).
    kernel_backend: str = "jnp"
    # Time-varying fault schedule (sim/faults.py): scheduled link/host
    # flaps, fractional-credit degrades and seeded per-link corruption.
    # Entry COUNTS are static (program cache key); every time/probability
    # value and the PRNG seed ride in as traced data, so one compiled
    # program serves any schedule of the same shape.  None = no faults
    # (and the fault stages vanish from the program entirely).
    faults: Optional[FaultSpec] = None

    @property
    def pfc_enabled(self) -> bool:
        return self.pfc if self.pfc is not None else (
            self.protocol == "rocev2")


def _bwhere(mask, new, old):
    """tree-where with a leading mask broadcast over trailing dims."""
    return jax.tree.map(
        lambda n, o: jnp.where(
            mask.reshape(mask.shape + (1,) * (n.ndim - mask.ndim)), n, o),
        new, old)


def _scatter_rows(tree_all, tree_rows, idx, n):
    """Scatter rows into per-flow pytrees; idx == n hits a trash row."""
    def one(a, b):
        pad = jnp.zeros((1,) + a.shape[1:], a.dtype)
        return jnp.concatenate([a, pad], 0).at[idx].set(b)[:n]
    return jax.tree.map(one, tree_all, tree_rows)


def _scatter_add(vec, idx, val, n):
    pad = jnp.zeros((1,) + vec.shape[1:], vec.dtype)
    return jnp.concatenate([vec, pad], 0).at[idx].add(val)[:n]


def _gather_rows(tree, idx, n):
    """Gather rows from per-flow pytrees; idx == n reads a zero trash row
    (the dual of :func:`_scatter_rows` — active-set and shard lanes use it
    to pull compacted row subsets)."""
    def one(a):
        pad = jnp.zeros((1,) + a.shape[1:], a.dtype)
        return jnp.concatenate([a, pad], 0)[idx]
    return jax.tree.map(one, tree)


def _set_rows(vec, idx, val, n):
    """Flat-vector row set with a trash slot at idx == n."""
    pad = jnp.zeros((1,) + vec.shape[1:], vec.dtype)
    return jnp.concatenate([vec, pad], 0).at[idx].set(val)[:n]


def _scatter_pipe(pipe, rows, slot, fidx, valid, h, n):
    """Scatter per-delivery message rows into the [H, N] return pipe at
    per-flow slots (each flow's ACK rides its own reverse-path latency).
    Invalid entries hit the trash slot past the flattened pipe."""
    flat_idx = jnp.where(valid, slot * n + fidx, h * n)

    def one(a, b):
        flat = a.reshape((h * n,) + a.shape[2:])
        pad = jnp.zeros((1,) + flat.shape[1:], a.dtype)
        out = jnp.concatenate([flat, pad], 0).at[flat_idx].set(b)
        return out[:h * n].reshape(a.shape)

    return jax.tree.map(one, pipe, rows)


def _hop_delays(cfg: FabricConfig) -> dict:
    """Static per-hop delay constants the program closes over.

    Returns K (per-link propagation, whole ticks), D_same/D_cross (ACK
    return-pipe ticks for same-ToR / cross-ToR flows) and PD (PFC
    pause-frame propagation ticks).  In "perhop" mode the return delay is
    the remainder of the hop-exact round trip — float propagation and ACK
    serialization are rounded ONCE here, so the realized uncongested RTT
    stays within a tick of ``h * (mtu_ser + ack_ser + 2 * prop)``; the
    folded mode (or a ``delay_ticks`` override) reproduces the legacy
    single-constant pipe with no per-hop propagation.
    """
    net = cfg.net
    tick_us = net.mtu_serialize_us
    folded = cfg.ack_path == "folded" or cfg.delay_ticks is not None
    if folded:
        if cfg.delay_ticks is not None:
            d = int(cfg.delay_ticks)
        else:
            d = max(1, round(net.base_rtt_us / tick_us) - 3)
        K, D_same, D_cross = 0, d, d
    else:
        prop_us = (cfg.hop_prop_us if cfg.hop_prop_us is not None
                   else net.hop_prop_effective_us)
        k_f = prop_us / tick_us
        a_f = net.ack_serialize_us / tick_us
        K = int(round(k_f))

        def ret(hops):
            # hops = one-way store-and-forward stage count (NIC included);
            # the fabric's forward pass realizes (hops-1)*(1+K) ticks, the
            # pipe carries the rest of the exact round trip
            rtt_f = hops * (1.0 + a_f + 2.0 * k_f)
            return max(1, int(round(rtt_f - (hops - 1) * (1 + K))))

        D_same, D_cross = ret(2), ret(4)
    if cfg.pfc_delay_ticks is not None:
        PD = max(0, int(cfg.pfc_delay_ticks))
    else:
        PD = K
    return dict(K=K, D_same=D_same, D_cross=D_cross, PD=PD,
                H=max(D_same, D_cross) + 2)


#: Chunk width of the sort-free ranker: candidates split into blocks of
#: this size; each block is resolved with a dense lower-triangle count and
#: blocks are combined through a scatter-add table + exclusive cumsum.
#: Intra-block work is O(M * CHUNK) and the cross-block table is
#: O(M / CHUNK * n_queues) memory, so CHUNK trades flat FLOPs against
#: table footprint; 256 keeps both small from 1K through 8K hosts.
_RANK_CHUNK = 256


def _rank_in_queue_argsort(qid: jax.Array, flag: jax.Array) -> jax.Array:
    """Stable-argsort reference ranker, O(M log M) — kept as a second
    independent implementation for the property tests (the hot path uses
    the sort-free :func:`_rank_in_queue`).  Same contract: rank among
    flag-set candidates of the same queue in candidate-index order, with
    an explicit ``-1`` fill at non-flagged entries."""
    m = qid.shape[0]
    key = qid * 2 + (~flag).astype(jnp.int32)
    order = jnp.argsort(key, stable=True)
    sq = qid[order]
    start = jnp.searchsorted(sq, sq, side="left").astype(jnp.int32)
    rank_sorted = jnp.arange(m, dtype=jnp.int32) - start
    ranks = jnp.zeros((m,), jnp.int32).at[order].set(rank_sorted)
    return jnp.where(flag, ranks, -1)


def _rank_in_queue(qid: jax.Array, flag: jax.Array,
                   n_queues: int) -> jax.Array:
    """Rank of each candidate among flag-set candidates of the same queue,
    in candidate-index order; non-flagged entries are ``-1`` (explicit
    masked fill — callers must not read ranks where ``flag`` is unset).

    Sort-free and fully parallel (no sequential carry): candidates split
    into ``_RANK_CHUNK``-wide blocks; a single scatter-add builds the
    [n_blocks, n_queues] table of flagged counts per (block, queue), an
    exclusive cumsum over the block axis turns it into each block's
    per-queue starting rank, and a batched dense lower-triangle count
    resolves ordering within blocks.  O(M * CHUNK) flat work — the
    "scatter-add / segmented-cumsum" replacement for the old per-tick
    stable argsort (O(M log M) with sort constants); ``n_queues`` is
    static so the table is fixed-shape.
    """
    m = qid.shape[0]
    c = _RANK_CHUNK
    qid = qid.astype(jnp.int32)
    if m == 0:
        return jnp.zeros((0,), jnp.int32)
    pad = (-m) % c
    if pad:
        qid_p = jnp.concatenate(
            [qid, jnp.full((pad,), n_queues, jnp.int32)])
        flag_p = jnp.concatenate([flag, jnp.zeros((pad,), bool)])
    else:
        qid_p, flag_p = qid, flag
    nb = qid_p.shape[0] // c
    qc = qid_p.reshape(nb, c)
    fc = flag_p.reshape(nb, c)
    # cross-block base: flagged count of each (earlier block, same queue);
    # one flat scatter-add (non-flagged entries land in the n_queues trash
    # column) then an exclusive cumsum down the block axis
    qw = n_queues + 1
    blk = jnp.repeat(jnp.arange(nb, dtype=jnp.int32), c)
    slot = blk * qw + jnp.where(flag_p, qid_p, n_queues)
    tbl = jnp.zeros((nb * qw,), jnp.int32).at[slot].add(
        flag_p.astype(jnp.int32)).reshape(nb, qw)
    start = jnp.cumsum(tbl, axis=0) - tbl
    base = start.reshape(-1)[blk * qw + qid_p]
    # intra-block: dense strictly-lower-triangle same-queue count
    tril = jnp.tril(jnp.ones((c, c), bool), k=-1)
    intra = jnp.sum((qc[:, :, None] == qc[:, None, :])
                    & fc[:, None, :] & tril[None, :, :],
                    axis=2).astype(jnp.int32)
    ranks = base + intra.reshape(-1)
    return jnp.where(flag, ranks[:m], -1)


def _make_protocol(cfg: FabricConfig):
    """Resolve cfg -> (Protocol, ecn kmin/kmax in packets)."""
    net = cfg.net
    if cfg.protocol == "strack":
        p = make_strack_params(net, max_paths=cfg.max_paths)
        proto = make_strack_protocol(p)
        kmin_p = net.ecn_kmin_bytes / net.mtu_bytes
        kmax_p = net.ecn_kmax_bytes / net.mtu_bytes
        target_qdelay_us = p.target_qdelay_us
    elif cfg.protocol == "rocev2":
        rp = cfg.roce or make_roce_params(net)
        proto = make_rocev2_protocol(make_roce_fab_params(net, rp))
        # "ECN threshold to one BDP for DCQCN" (paper Section 4.1)
        kmin_p = rp.ecn_kmin_bdp * net.bdp_pkts
        kmax_p = rp.ecn_kmax_bdp * net.bdp_pkts
        target_qdelay_us = net.base_rtt_us
    else:
        raise ValueError(f"unknown protocol {cfg.protocol!r}; "
                         f"expected one of {PROTOCOLS}")
    return proto, kmin_p, kmax_p, target_qdelay_us


def _rto_us(cfg: "FabricConfig") -> float:
    """The resolved protocol's retransmission timeout (us) — the unit the
    chaos recovery gates and per-flap-window attribution derive from."""
    if cfg.protocol == "strack":
        return make_strack_params(cfg.net, max_paths=cfg.max_paths).rto_us
    rp = cfg.roce or make_roce_params(cfg.net)
    return make_roce_fab_params(cfg.net, rp).rto_us


def _make_program(topo: FatTree, n_flows: int, n_ticks: int,
                  cfg: FabricConfig, dep: Optional[DepSpec] = None,
                  n_real: Optional[int] = None):
    """Build the pure jnp fabric program for fixed (topology, N, ticks).

    Returns ``program(src, dst, total_pkts, tail_bytes, ent0, lb_code) ->
    (final_state, tick_metrics)`` — jittable and vmappable (the sweep
    helpers vmap it over stacked flow arrays).  ``lb_code`` is the traced
    ``LB_MODES`` index, so one compiled program serves every STrack spray
    mode (and every entropy seed / message-size pattern); ``tail_bytes``
    is each flow's odd-tail wire size (data, like sizes).  ``dep`` is the
    static message/dependency structure the program closes over; ``None``
    means one deps-free message per flow.

    Programs are expensive to build and trace: go through
    :func:`_get_program`, which caches them on the static dims.  Every
    call here bumps ``program_builds`` — the regression hook the cache
    tests key on.
    """
    global program_builds
    program_builds += 1
    if cfg.lb_mode not in LB_MODES:
        raise ValueError(f"unknown lb_mode {cfg.lb_mode!r}; "
                         f"expected one of {LB_MODES}")
    if cfg.ack_path not in ACK_PATHS:
        raise ValueError(f"unknown ack_path {cfg.ack_path!r}; "
                         f"expected one of {ACK_PATHS}")
    if cfg.trace_every < 0:
        raise ValueError(f"trace_every must be >= 0, got {cfg.trace_every}")
    # the event-horizon scan cannot stack a per-tick trace (its trip count
    # is data-dependent): warp runs are events-only summaries
    trace_every = 0 if cfg.time_warp else cfg.trace_every
    DP = int(cfg.shard) if int(cfg.shard) > 1 else 1
    A = int(cfg.active_cap) if cfg.active_cap else 0
    if cfg.kernel_backend not in KERNEL_BACKENDS:
        raise ValueError(f"unknown kernel_backend {cfg.kernel_backend!r}; "
                         f"expected one of {KERNEL_BACKENDS}")
    use_kernels = cfg.kernel_backend != "jnp"
    interpret = cfg.kernel_backend == "pallas_interpret"
    if use_kernels and DP > 1:
        raise ValueError(
            f"kernel_backend={cfg.kernel_backend!r} requires shard <= 1: "
            f"the sharded program's all_gather exchanges cannot run "
            f"inside a Pallas kernel body")
    if A < 0:
        raise ValueError(f"active_cap must be positive, got {A}")
    if A and trace_every:
        raise ValueError(
            "active_cap requires trace_every=0 (or time_warp): the dense "
            "trace samples all-flow means the active set skips")
    if DP > 1:
        if A:
            raise ValueError("active_cap and shard are mutually exclusive")
        if trace_every:
            raise ValueError(
                "shard requires trace_every=0 (or time_warp): the per-tick "
                "trace is not defined on the sharded program")
        n_dev = len(jax.devices())
        if n_dev < DP:
            raise ValueError(
                f"cfg.shard={DP} needs {DP} devices but only {n_dev} are "
                f"visible; on CPU hosts export "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={DP}")
    net = cfg.net
    proto, kmin_p, kmax_p, _ = _make_protocol(cfg)
    pfc = cfg.pfc_enabled
    # Static fault-shape gates (sim/faults.py): entry COUNTS decide which
    # chaos code paths exist in the trace — when a class is absent its
    # entire path vanishes, so fault-free programs stay bit-identical to
    # the pre-chaos fabric.  The VALUES (times, probabilities, seed) ride
    # in as the traced FaultData argument.
    faults = cfg.faults if cfg.faults is not None else FaultSpec()
    F_ROW = (2 * len(faults.link_flaps) + len(faults.uplink_flaps)
             + len(faults.host_flaps))
    F_NIC = len(faults.host_flaps)
    F_UP = len(faults.link_flaps) + len(faults.uplink_flaps)
    F_DEG = 2 * len(faults.link_degrade)
    F_COR = 2 * len(faults.link_corrupt) + len(faults.host_corrupt)
    FW = faults.n_flap_windows
    HAS_FAULTS = faults.total_entries > 0
    # per-flap-window retransmit attribution covers the flap plus two
    # RTOs of recovery afterglow
    rto_ticks = int(math.ceil(_rto_us(cfg) / net.mtu_serialize_us))
    at = ArrayTopo.from_fat_tree(topo)
    T, S, NH = at.n_tor, at.n_spine, at.n_hosts
    HPT = at.hosts_per_tor
    TS = T * S
    Q = 2 * TS + NH                     # tor_up + spine_down + host_down
    N = n_flows
    if N <= 0:
        raise ValueError("fabric program needs at least one flow")
    # NR: the "real" (pre-padding) flow count.  The sharded path pads the
    # flow axis to a device multiple with inert zero-packet flows; NIC
    # round-robin arbitration keys on NR so padded and unpadded programs
    # arbitrate identically (bit-exact shard-vs-unsharded parity).
    NR = int(n_real) if n_real is not None else N
    if DP > 1 and N % DP != 0:
        raise ValueError(f"sharded flow axis must be a multiple of "
                         f"shard={DP}, got {N} (callers pad with inert "
                         f"flows via _shard_pad_inputs)")
    if A >= N:
        A = 0  # cap >= N: the dense formulation is already minimal
    NL = N // DP                     # flow lanes per pod
    QRL = -(-(Q + 1) // DP)          # ring rows per pod (global trash incl.)
    QR = QRL * DP
    if dep is None:
        dep = _trivial_dep(range(N))
    n_msgs, n_groups = dep.n_msgs, dep.n_groups
    n_edges = int(dep.edge_parent.shape[0])

    tick_us = net.mtu_serialize_us
    drop_pkts = int(net.drop_bytes // net.mtu_bytes)
    buffer_pkts = int(cfg.switch_buffer_bytes // net.mtu_bytes)
    # worst-case same-tick arrivals at one queue: every ToR host injecting
    # data+probe (tor_up / host_down) or every spine/ToR handing down a pkt
    max_extra = max(T, S + 2 * HPT)
    if pfc:
        # lossless: PFC backpressure bounds the queues; data is only shed
        # at the (never-expected) ring hard cap
        data_drop_pkts = buffer_pkts + max_extra
        hard_pkts = data_drop_pkts
    else:
        data_drop_pkts = drop_pkts
        hard_pkts = drop_pkts + max_extra  # probes squeeze past data drop
    cap = hard_pkts + max_extra + 2
    hd = _hop_delays(cfg)
    K, D_same, D_cross, PD, H = (hd["K"], hd["D_same"], hd["D_cross"],
                                 hd["PD"], hd["H"])
    n_ports = NH + 2 * TS            # PFC delay-line width (nic | sd | up)

    mtu_f = jnp.float32(net.mtu_bytes)
    ack_f = jnp.float32(ACK_WIRE_BYTES)
    buffer_b = jnp.float32(cfg.switch_buffer_bytes)
    qrows = jnp.arange(Q, dtype=jnp.int32)
    is_up_row = qrows < TS
    spine_of_row = jnp.where(is_up_row, qrows % S, (qrows - TS) // T)
    host_tor = jnp.arange(NH, dtype=jnp.int32) // HPT

    def body(src, dst, total_pkts, tail_b, ent0, lb_code, arrival, fd):
        # Bump the retrace counter at TRACE time (python side effects fire
        # once per jax trace, not per run) — the job-batching regression
        # hook: bucketed batch sizes must not retrace this body.
        global program_traces
        program_traces += 1
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)
        total_pkts = jnp.asarray(total_pkts, jnp.int32)
        tail_b = jnp.asarray(tail_b, jnp.float32)
        lb_code = jnp.asarray(lb_code, jnp.int32)
        # per-MESSAGE earliest-launch tick (open-loop arrivals); plain
        # traced data, so one compiled program serves every arrival
        # pattern — all-zero degenerates to the closed-loop semantics
        arrival = jnp.asarray(arrival, jnp.int32)
        src_tor = src // HPT
        dst_tor = dst // HPT
        same_tor = src_tor == dst_tor
        iota_n = jnp.arange(N, dtype=jnp.int32)
        fixed_ent = ecmp_mix(src, dst, iota_n) % cfg.max_paths
        # per-flow ACK return latency: the reverse path's store-and-forward
        # pipeline (2 hops same-ToR, 4 cross-ToR; one constant in folded
        # mode where D_same == D_cross)
        dflow = jnp.where(same_tor, jnp.int32(D_same), jnp.int32(D_cross))

        if DP > 1:
            # pod-local offsets: flow lanes [foff, foff+NL), ring rows
            # [qoff, qoff+QRL) live on this pod; everything else replicated
            pod = jax.lax.axis_index("pod")
            foff = pod * NL
            qoff = pod * QRL

            def fslice(x):
                """This pod's [NL] slice of a replicated [N] flow vector."""
                return jax.lax.dynamic_slice_in_dim(x, foff, NL)

            def gath(tree):
                """Concatenate pod-local leading axes back to global."""
                return jax.tree.map(
                    lambda a: jax.lax.all_gather(a, "pod", tiled=True),
                    tree)

        def wire_bytes(flow, psn, probe):
            """Per-packet wire size: probes are ACK-sized, the final PSN
            of a message is its odd tail, everything else a full MTU."""
            f = jnp.clip(flow, 0, N - 1)
            tail = psn >= total_pkts[f] - 1
            return jnp.where(probe, ack_f,
                             jnp.where(tail, tail_b[f], mtu_f))

        if DP > 1:
            fl0, rcv0 = proto.init(fslice(total_pkts), fslice(tail_b),
                                   fslice(ent0))
            q_rows = QRL
        else:
            fl0, rcv0 = proto.init(total_pkts, tail_b, ent0)
            q_rows = Q + 1
        q0 = PktQ(flow=jnp.full((q_rows, cap), -1, jnp.int32),
                  psn=jnp.zeros((q_rows, cap), jnp.int32),
                  ts=jnp.zeros((q_rows, cap), jnp.float32),
                  probe=jnp.zeros((q_rows, cap), bool),
                  ecn=jnp.zeros((q_rows, cap), bool),
                  ent=jnp.zeros((q_rows, cap), jnp.int32),
                  ready=jnp.zeros((q_rows, cap), jnp.int32),
                  spine=jnp.zeros((q_rows, cap), jnp.int32))
        st0 = FabricState(
            flows=fl0, rcv=rcv0, q=q0,
            qhead=jnp.zeros((Q + 1,), jnp.int32),
            qsize=jnp.zeros((Q + 1,), jnp.int32),
            pipe=proto.empty_msgs(H, NL if DP > 1 else N),
            obl_rr=iota_n % cfg.max_paths,  # stagger oblivious spray starts
            drops=jnp.zeros((), jnp.int32),
            delivered=jnp.zeros((N,), jnp.float32),
            done_tick=jnp.full((N,), -1, jnp.int32),
            qbytes=jnp.zeros((Q + 1,), jnp.float32),
            ing_host=jnp.zeros((NH,), jnp.float32),
            ing_sd=jnp.zeros((S, T), jnp.float32),
            ing_up=jnp.zeros((T, S), jnp.float32),
            paused_nic=jnp.zeros((NH,), bool),
            paused_sd=jnp.zeros((S, T), bool),
            paused_up=jnp.zeros((T, S), bool),
            pfc_line=jnp.zeros((max(PD, 1), n_ports), bool),
            pauses=jnp.zeros((), jnp.int32),
            pending=dep.init_pending,
            msg_done=jnp.zeros((n_msgs,), bool),
            msg_release_tick=jnp.full((n_msgs,), -1, jnp.int32),
            msg_done_tick=jnp.full((n_msgs,), -1, jnp.int32),
            group_done_tick=jnp.full((n_groups,), -1, jnp.int32),
            act_overflow=jnp.zeros((), jnp.int32),
            ecn_marks=jnp.zeros((), jnp.int32),
            qdepth_hi=jnp.zeros((Q + 1,), jnp.int32),
            blackholed=jnp.zeros((), jnp.int32),
            corrupt_drops=jnp.zeros((), jnp.int32),
            tx_rows=jnp.zeros((Q + 1,), jnp.int32),
            win_retx=jnp.zeros((FW,), jnp.int32))

        # ---- kernel-backend dispatch ---------------------------------
        # The hot stages below are *core* functions over explicit
        # operands, called either inline (kernel_backend="jnp" — XLA
        # fuses them exactly as before) or through the fused-stage
        # Pallas kernels, which run the SAME core inside one
        # pallas_call: one implementation, two execution substrates,
        # bit-exact by construction (tests/test_fabric_kernels.py + the
        # fuzz suite's kernel leg).  The sharded program (DP > 1) keeps
        # its inline jnp stages: its all_gather exchanges cannot live in
        # a kernel body.
        if use_kernels:
            def _trans(core, args):
                return flow_transition_kernel(core, args,
                                              interpret=interpret)

            def _serve(core, args):
                return serve_enqueue_kernel(core, args,
                                            interpret=interpret)
        else:
            def _trans(core, args):
                return core(*args)
            _serve = _trans

        def timers_of(fl, now):
            return jax.vmap(lambda f: proto.on_timer(f, now))(fl)

        def empty_tx(n):
            return tp.TxPacket(
                valid=jnp.zeros((n,), bool),
                psn=jnp.zeros((n,), jnp.int32),
                entropy=jnp.zeros((n,), jnp.int32),
                is_rtx=jnp.zeros((n,), bool),
                is_probe=jnp.zeros((n,), bool))

        def dense_trans_core(flows0, due, sendable, eff_nic, src_, t):
            """Kernel-3 core, dense variant: due-ACK apply, timer sweep,
            next-packet offers and NIC round-robin arbitration over all
            N flow lanes (see flow_transition_kernel)."""
            now = t.astype(jnp.float32) * tick_us
            lanes = iota1(N)
            fl = jax.vmap(lambda f, m: proto.on_ack(f, m, now))(
                flows0, due)
            # Gated (dependency-pending) flows keep their init-time
            # timer state — their deadlines effectively start counting
            # at release, as in the oracle where timers are armed at
            # add_flow time.
            fl_t, probe_tx = jax.lax.cond(
                (t % cfg.timer_every) == 0,
                lambda f: timers_of(f, now),
                lambda f: (f, empty_tx(N)), fl)
            probe_valid = probe_tx.valid & sendable
            if pfc:
                # A paused NIC emits nothing.  Withhold the timer-state
                # commit for flows whose probe was blocked (their probe
                # deadline and spray state stay put), so the probe is
                # *delayed* until resume — as in the oracle, where it
                # waits in the paused NIC queue — not silently lost.
                blocked = probe_tx.valid & eff_nic[src_]
                fl = _bwhere(sendable & (~blocked), fl_t, fl)
                probe_valid = probe_valid & (~blocked)
            else:
                fl = _bwhere(sendable, fl_t, fl)
            fl_sent, tx = jax.vmap(
                lambda f: proto.next_packet(f, now))(fl)
            can_tx = tx.valid & sendable
            score = jnp.where(can_tx, (lanes - t) % NR, NR)
            best = jax.ops.segment_min(score, src_, num_segments=NH)
            sel = can_tx & (score == best[src_])
            if pfc:
                # a paused NIC injects nothing (state update withheld
                # too, so the flow re-offers the same packet next tick)
                sel = sel & (~eff_nic[src_])
            fl = _bwhere(sel, fl_sent, fl)
            return fl, tx, probe_tx, probe_valid, sel, can_tx

        def active_trans_core(flows0, pipe_cur, act_idx, eff_nic, src_,
                              t):
            """Kernel-3 core, active-set variant: the <= A released
            not-done lanes are gathered from the [N] flow state, stepped
            and scattered back inside the core, so the [A]-shaped flow
            pytrees never materialize outside the kernel call."""
            now = t.astype(jnp.float32) * tick_us
            lane_ok = act_idx < N
            act_clip = jnp.minimum(act_idx, N - 1)
            lane_src = src_[act_clip]
            due = _gather_rows(pipe_cur, act_idx, N)
            rows = _gather_rows(flows0, act_idx, N)
            rows = jax.vmap(lambda f, m: proto.on_ack(f, m, now))(
                rows, due)
            rows_t, probe_tx = jax.lax.cond(
                (t % cfg.timer_every) == 0,
                lambda f: timers_of(f, now),
                lambda f: (f, empty_tx(A)), rows)
            probe_valid = probe_tx.valid & lane_ok
            if pfc:
                blocked = probe_tx.valid & eff_nic[lane_src]
                rows = _bwhere(lane_ok & (~blocked), rows_t, rows)
                probe_valid = probe_valid & (~blocked)
            else:
                rows = _bwhere(lane_ok, rows_t, rows)
            rows_sent, tx = jax.vmap(
                lambda f: proto.next_packet(f, now))(rows)
            can_tx = tx.valid & lane_ok
            score = jnp.where(can_tx, (act_idx - t) % NR, NR)
            best = jax.ops.segment_min(score, lane_src,
                                       num_segments=NH)
            sel = can_tx & (score == best[lane_src])
            if pfc:
                sel = sel & (~eff_nic[lane_src])
            rows = _bwhere(sel, rows_sent, rows)
            fl = _scatter_rows(flows0, rows,
                               jnp.where(lane_ok, act_idx, N), N)
            # non-lane flows cannot change done-ness (only ACK
            # processing completes a flow, and every released not-done
            # flow is a lane), so per-lane done bits suffice for the
            # completion step
            done_lane = jax.vmap(proto.done)(rows)
            return (fl, tx, probe_tx, probe_valid, sel, can_tx,
                    done_lane)

        def serve_enqueue_core(qtree, qhead0, qsize0, paused_row, dst_,
                               dst_tor_, total_pkts_, tail_b_,
                               lane_flow, tx_psn, probe_psn, ent_d,
                               ent_p, inj_sp, inj_spp, sel, probe_valid,
                               inj_q, inj_qp, row_down, row_duty,
                               row_cor_p, fseed, t):
            """Kernel-1 core: fused queue-ring service + two-pass
            enqueue.  Serve: every unpaused queue pops its head packet
            once the head's departure-time lane says it has arrived
            (upstream serialization + link propagation accrued), with
            occupancy-fraction ECN marking.  Enqueue: fabric advances +
            NIC data/probe injections rank among same-queue candidates
            (all-pairs mask when small, the sort-free chunked ranker —
            kernel 2 — at scale), drop on occupancy and scatter into the
            flat rings with next-hop departure times (see
            serve_enqueue_kernel)."""
            now = t.astype(jnp.float32) * tick_us
            qrows_ = iota1(Q)
            is_up = qrows_ < TS
            spine_row = jnp.where(is_up, qrows_ % S, (qrows_ - TS) // T)

            def wire(flow, psn, probe):
                """Per-packet wire size: probes are ACK-sized, the final
                PSN of a message is its odd tail, else a full MTU."""
                f = jnp.clip(flow, 0, N - 1)
                tail = psn >= total_pkts_[f] - 1
                return jnp.where(probe, jnp.float32(ACK_WIRE_BYTES),
                                 jnp.where(tail, tail_b_[f],
                                           jnp.float32(net.mtu_bytes)))

            # serve: pop ready heads, ECN-mark on occupancy fraction
            qs = qsize0[:Q]
            if pfc:
                has = (qs > 0) & (~paused_row)
            else:
                has = qs > 0
            hidx = qhead0[:Q] % cap
            pop = PktQ(*[f[qrows_, hidx] for f in qtree])
            has = has & (pop.ready <= t)
            if row_duty is not None:
                # degraded rows serve only on duty-cycle-open ticks
                has = has & row_duty
            residual = jnp.maximum(qs - 1, 0).astype(jnp.float32)
            frac = jnp.clip((residual - kmin_p)
                            / jnp.maximum(kmax_p - kmin_p, 1e-9),
                            0.0, 1.0)
            dither = jnp.abs(jnp.sin(
                t.astype(jnp.float32) * 12.9898
                + qrows_.astype(jnp.float32) * 78.233))
            mark = has & (~pop.probe) & (frac > dither * 0.999)
            ecn_out = pop.ecn | mark
            served = has.astype(jnp.int32)
            qhead1 = qhead0.at[:Q].add(served)
            qsize1 = qsize0.at[:Q].add(-served)

            # chaos: a down link still serves (its buffer drains) but
            # everything it pops is blackholed; corruption drops data
            # packets on a counter-keyed u01 draw.  Both remove the
            # packet from the advance/delivery candidate set; PFC
            # dequeue accounting keeps the original ``has`` (the packet
            # really left the buffer).
            surv = has
            bh_add = jnp.zeros((), jnp.int32)
            cor_add = jnp.zeros((), jnp.int32)
            if row_down is not None:
                bh_add = jnp.sum(has & row_down).astype(jnp.int32)
                surv = surv & (~row_down)
            if row_cor_p is not None:
                u = fault_u01(fseed, qrows_, t, pop.psn)
                corrupt = surv & (~pop.probe) & (u < row_cor_p)
                cor_add = jnp.sum(corrupt).astype(jnp.int32)
                surv = surv & (~corrupt)

            fclip = jnp.clip(pop.flow, 0, N - 1)
            pop_bytes = wire(pop.flow, pop.psn, pop.probe)
            # fabric advance targets (tor_up -> spine_down -> host_down)
            adv_tgt = jnp.where(
                is_up, TS + spine_row * T + dst_tor_[fclip],
                2 * TS + dst_[fclip])[:2 * TS]
            adv_valid = surv[:2 * TS]

            # enqueue: fabric advances + data + probes
            L_ = lane_flow.shape[0]
            cand_qid = jnp.concatenate([adv_tgt, inj_q, inj_qp])
            cand_valid = jnp.concatenate([adv_valid, sel, probe_valid])
            now_l = jnp.full((L_,), now, jnp.float32)
            zb, ob = jnp.zeros((L_,), bool), jnp.ones((L_,), bool)
            # every enqueue (fabric advance or NIC injection) arrives at
            # the next stage after 1 tick of serialization + K ticks of
            # link propagation — the per-hop departure-time lane
            cand = PktQ(
                flow=jnp.concatenate(
                    [pop.flow[:2 * TS], lane_flow, lane_flow]),
                psn=jnp.concatenate(
                    [pop.psn[:2 * TS], tx_psn, probe_psn]),
                ts=jnp.concatenate([pop.ts[:2 * TS], now_l, now_l]),
                probe=jnp.concatenate([pop.probe[:2 * TS], zb, ob]),
                ecn=jnp.concatenate([ecn_out[:2 * TS], zb, zb]),
                ent=jnp.concatenate([pop.ent[:2 * TS], ent_d, ent_p]),
                ready=jnp.full((2 * TS + 2 * L_,), 0, jnp.int32)
                + t + 1 + K,
                spine=jnp.concatenate(
                    [pop.spine[:2 * TS], inj_sp, inj_spp]))
            # per-candidate wire bytes (PFC accounting is per-packet)
            cand_bytes = jnp.concatenate([
                pop_bytes[:2 * TS],
                wire(lane_flow, tx_psn, zb),
                wire(lane_flow, probe_psn, ob)])
            # Two-pass enqueue. Pass 1: drop decision from the occupancy
            # bound qsize + rank-among-valid (over-counts same-tick
            # earlier drops by design — the queue is at threshold then
            # anyway).  Pass 2: ring positions from rank-among-ACCEPTED,
            # so accepted packets pack the ring contiguously and a drop
            # never leaves a stale gap slot.  Small candidate counts use
            # the all-pairs mask (cheaper than the sweep); at scale the
            # sort-free chunked scatter-add ranker runs in O(M * CHUNK)
            # flat work.
            M = 2 * TS + 2 * L_
            if M <= 256:
                tril = (jax.lax.broadcasted_iota(jnp.int32, (M, M), 1)
                        < jax.lax.broadcasted_iota(jnp.int32, (M, M),
                                                   0))
                same_q = cand_qid[:, None] == cand_qid[None, :]

                def rank_among(flag):
                    return jnp.sum(same_q & flag[None, :] & tril,
                                   axis=1).astype(jnp.int32)
            elif use_kernels:
                def rank_among(flag):
                    return rank_in_queue_core(cand_qid, flag, Q)
            else:
                def rank_among(flag):
                    return _rank_in_queue(cand_qid, flag, Q)
            rank_v = rank_among(cand_valid)
            occ = qsize1[cand_qid] + rank_v
            dropped = cand_valid & (
                ((~cand.probe) & (occ >= data_drop_pkts))
                | (occ >= hard_pkts))
            accept = cand_valid & (~dropped)
            rank_a = rank_among(accept)
            pos = (qhead1[cand_qid] + qsize1[cand_qid] + rank_a) % cap
            flat_idx = jnp.where(accept, cand_qid * cap + pos, Q * cap)
            q1 = PktQ(*[f.reshape(-1).at[flat_idx].set(v)
                        .reshape(Q + 1, cap)
                        for f, v in zip(qtree, cand)])
            added = jax.ops.segment_sum(
                accept.astype(jnp.int32),
                jnp.where(accept, cand_qid, Q), num_segments=Q + 1)
            qsize2 = (qsize1 + added).at[Q].set(0)
            qhead2 = qhead1.at[Q].set(0)
            drops_add = jnp.sum(dropped).astype(jnp.int32)
            return (q1, qhead2, qsize2, pop, has, surv, ecn_out,
                    pop_bytes, cand_qid, cand_bytes, accept, drops_add,
                    bh_add, cor_add)

        def tick(st: FabricState, t):
            """One dense tick at tick-index ``t`` -> (new_state, can_any).

            ``can_any`` is whether any released flow offered a data packet
            this tick — the send half of the idleness test the time-warp
            scan uses (timer/pacing/pipe wakeups are handled by
            ``warp_target``).

            Stage order (reordered from the historical serve-first
            layout so each hot stage is one kernel call; equivalent
            because the transport lanes never read this tick's pops, and
            the return-pipe slot the receivers write, (t + D[flow]) % H
            with 1 <= D[flow] <= H - 2, is always distinct from the slot
            t % H the transport stage reads and clears): dependency
            gate; PFC effective-pause masks; per-flow transport lanes
            (kernel 3); spray/entropy + ECMP injection targets; fused
            ring service + enqueue (kernel 1, ranking via kernel 2);
            deliveries -> receivers + return-pipe writes; PFC
            accounting; completion.
            """
            now = t.astype(jnp.float32) * tick_us

            # ---- 0. dependency gate: a message is sendable the tick its
            # pending-dep counter reaches zero AND its arrival tick has
            # come (deps-free, arrival-0 traces: always) ------------------
            sendable_msg = (st.pending <= 0) & (arrival <= t)
            sendable = sendable_msg[dep.msg_of_flow]
            msg_release_tick = jnp.where(
                sendable_msg & (st.msg_release_tick < 0),
                t.astype(jnp.int32), st.msg_release_tick)

            # ---- 0b. PFC effective-pause masks: the decision from PD
            # ticks ago (pause frames propagate one hop upstream), read
            # by both the NIC gate (transport) and the serve step -------
            if pfc:
                if PD > 0:
                    eff = st.pfc_line[t % PD]
                    eff_nic = eff[:NH]
                    eff_sd = eff[NH:NH + TS].reshape(S, T)
                    eff_up = eff[NH + TS:].reshape(T, S)
                else:
                    eff_nic, eff_sd, eff_up = (st.paused_nic,
                                               st.paused_sd,
                                               st.paused_up)
                paused_row = jnp.concatenate(
                    [eff_up.reshape(-1), eff_sd.reshape(-1),
                     jnp.zeros((NH,), bool)])
            else:
                # None leaves vanish under pytree flattening, so the
                # kernel wrappers pass these through untouched
                eff_nic = paused_row = None

            # ---- 0c. chaos masks: per-tick link state from the traced
            # fault schedule (sim/faults.py).  Entry counts are static,
            # so every branch below vanishes from fault-free programs;
            # inactive windows (t outside [t0, t1)) scatter into the
            # trash row, so inert entries are exact no-ops.
            ti = t.astype(jnp.int32)
            if F_ROW > 0:
                f_act = (fd.flap_row_t0 <= ti) & (ti < fd.flap_row_t1)
                row_down = jnp.zeros((Q + 1,), bool).at[
                    jnp.where(f_act, fd.flap_row, Q)].set(True)[:Q]
            else:
                row_down = None
            if F_NIC > 0:
                n_act = (fd.flap_nic_t0 <= ti) & (ti < fd.flap_nic_t1)
                nic_down = jnp.zeros((NH + 1,), bool).at[
                    jnp.where(n_act, fd.flap_nic, NH)].set(True)[:NH]
            else:
                nic_down = None
            if F_DEG > 0:
                d_act = (fd.deg_t0 <= ti) & (ti < fd.deg_t1)
                d_closed = d_act & (~duty_open(ti, fd.deg_num))
                row_duty = jnp.ones((Q + 1,), bool).at[
                    jnp.where(d_closed, fd.deg_row, Q)].set(False)[:Q]
            else:
                row_duty = None
            if F_COR > 0:
                c_act = (fd.cor_t0 <= ti) & (ti < fd.cor_t1)
                row_cor_p = jnp.zeros((Q + 1,), jnp.float32).at[
                    jnp.where(c_act, fd.cor_row, Q)].max(fd.cor_p)[:Q]
                fseed = fd.seed
            else:
                row_cor_p = None
                fseed = None
            if F_UP > 0:
                # flapped uplinks leave the ECMP candidate set for the
                # flap window.  Live spines in ascending order via a
                # stable argsort on the down-mask — exactly the static
                # live_list construction, so with no flap active this is
                # bit-identical to at.ecmp_spine.
                u_act = (fd.flap_up_t0 <= ti) & (ti < fd.flap_up_t1)
                up_down = jnp.zeros((TS + 1,), bool).at[
                    jnp.where(u_act, fd.flap_up, TS)].set(
                    True)[:TS].reshape(T, S)
                live_now = at.live_mask & (~up_down)
                n_live_now = jnp.maximum(
                    jnp.sum(live_now, axis=1).astype(jnp.int32), 1)
                live_order = jnp.argsort(~live_now, axis=1,
                                         stable=True).astype(jnp.int32)

                def pick_spine(s_, d_, e_):
                    tor_ = s_ // HPT
                    k_ = ecmp_mix(s_, d_, e_) % n_live_now[tor_]
                    return live_order[tor_, k_]
            else:
                pick_spine = at.ecmp_spine

            # ---- 1. transport lanes: due ACKs, timers, sends (kernel 3)
            # Three equivalent lane formulations of the same per-flow
            # steps (all bit-exact in observables — the fuzz suite pins
            # them against each other):
            #   * dense (default): lanes are all N flows,
            #   * active-set: lanes are the <= A flows that are released
            #     and not done, compacted with a fill-value nonzero (the
            #     ascending index order preserves candidate order, hence
            #     ranks, drops and ring layout),
            #   * sharded: this pod's NL flow lanes; NIC offers cross pods
            #     through an all_gather so arbitration stays global.
            # The transport stage reads + clears return-pipe slot t % H
            # BEFORE the receivers (stage 3 below) write slot
            # (t + D[flow]) % H — always a different slot, so this is
            # order-independent.
            cur = t % H
            overflow = jnp.zeros((), jnp.int32)
            if DP > 1:
                due = jax.tree.map(lambda a: a[cur], st.pipe)
                flows_l = jax.vmap(lambda f, m: proto.on_ack(f, m, now))(
                    st.flows, due)
                pipe = st.pipe._replace(valid=st.pipe.valid.at[cur].set(
                    jnp.zeros((NL,), bool)))
                flows_t_l, probe_tx_l = jax.lax.cond(
                    (t % cfg.timer_every) == 0,
                    lambda f: timers_of(f, now),
                    lambda f: (f, empty_tx(NL)), flows_l)
                probe_tx = gath(probe_tx_l)
                probe_valid = probe_tx.valid & sendable
                if pfc:
                    blocked = probe_tx.valid & eff_nic[src]
                    flows_l = _bwhere(fslice(sendable & (~blocked)),
                                      flows_t_l, flows_l)
                    probe_valid = probe_valid & (~blocked)
                else:
                    flows_l = _bwhere(fslice(sendable), flows_t_l,
                                      flows_l)
                flows_sent_l, tx_l = jax.vmap(
                    lambda f: proto.next_packet(f, now))(flows_l)
                tx = gath(tx_l)
                can_tx = tx.valid & sendable
                score = jnp.where(can_tx, (iota_n - t) % NR, NR)
                best = jax.ops.segment_min(score, src, num_segments=NH)
                sel = can_tx & (score == best[src])
                if pfc:
                    sel = sel & (~eff_nic[src])
                flows = _bwhere(fslice(sel), flows_sent_l, flows_l)
                lane_flow, lane_src, lane_dst = iota_n, src, dst
                lane_same, lane_stor = same_tor, src_tor
                lane_fix, lane_rr = fixed_ent, st.obl_rr
                lane_idx, L = iota_n, N
            elif A:
                # active set: released, not-yet-done flows (ascending
                # flow index; fill lanes read/write the trash row).  The
                # compaction + overflow check stay outside the core
                # (nonzero's static-size fill semantics); the gathered
                # transitions run inside it.
                done_prev = jax.vmap(proto.done)(st.flows)
                act_mask = sendable & (~done_prev)
                act_idx = jnp.nonzero(
                    act_mask, size=A, fill_value=N)[0].astype(jnp.int32)
                lane_ok = act_idx < N
                act_clip = jnp.minimum(act_idx, N - 1)
                overflow = (jnp.sum(act_mask) > A).astype(jnp.int32)
                pipe_cur = jax.tree.map(lambda a: a[cur], st.pipe)
                (flows, tx, probe_tx, probe_valid, sel, can_tx,
                 done_lane) = _trans(
                    active_trans_core,
                    (st.flows, pipe_cur, act_idx, eff_nic, src, t))
                pipe = st.pipe._replace(valid=st.pipe.valid.at[cur].set(
                    jnp.zeros((N,), bool)))
                lane_flow, lane_src = act_clip, src[act_clip]
                lane_dst = dst[act_clip]
                lane_same, lane_stor = (same_tor[act_clip],
                                        src_tor[act_clip])
                lane_fix, lane_rr = (fixed_ent[act_clip],
                                     st.obl_rr[act_clip])
                lane_idx, L = act_idx, A
            else:
                due = jax.tree.map(lambda a: a[cur], st.pipe)
                flows, tx, probe_tx, probe_valid, sel, can_tx = _trans(
                    dense_trans_core,
                    (st.flows, due, sendable, eff_nic, src, t))
                pipe = st.pipe._replace(valid=st.pipe.valid.at[cur].set(
                    jnp.zeros((N,), bool)))
                lane_flow, lane_src, lane_dst = iota_n, src, dst
                lane_same, lane_stor = same_tor, src_tor
                lane_fix, lane_rr = fixed_ent, st.obl_rr
                lane_idx, L = iota_n, N

            if not proto.uses_spray:
                ent = tx.entropy
                ent_probe = probe_tx.entropy
                obl_rr = st.obl_rr
            else:
                # lb_mode is a traced scalar (LB_MODES index) so sweeps can
                # vmap spray modes through ONE compiled program; the
                # selects below are index arithmetic, not extra queue work.
                is_obl = lb_code == 1
                is_fix = lb_code == 2
                ent_obl = (lane_rr + 1) % cfg.max_paths
                ent = jnp.where(is_obl, ent_obl,
                                jnp.where(is_fix, lane_fix, tx.entropy))
                ent_probe = jnp.where(
                    is_obl, ent_obl,
                    jnp.where(is_fix, lane_fix, probe_tx.entropy))
                if A:
                    obl_rr = _set_rows(
                        st.obl_rr, jnp.where(is_obl & sel, lane_idx, N),
                        ent_obl, N)
                else:
                    obl_rr = jnp.where(is_obl & sel, ent_obl, st.obl_rr)

            spine = pick_spine(lane_src, lane_dst, ent)
            inj_q = jnp.where(lane_same, 2 * TS + lane_dst,
                              lane_stor * S + spine)
            spine_p = pick_spine(lane_src, lane_dst, ent_probe)
            inj_qp = jnp.where(lane_same, 2 * TS + lane_dst,
                               lane_stor * S + spine_p)

            # retransmit attempts COMMITTED this tick (before any NIC
            # blackhole: the attempt happened even into a dead cable) —
            # attributed to active flap windows below
            if FW > 0:
                rtx_n = jnp.sum(sel & tx.is_rtx).astype(jnp.int32)
            bh_nic = jnp.zeros((), jnp.int32)
            if nic_down is not None:
                # host->ToR uplink down: the flow commits its send state
                # (the NIC transmitted into a dead cable) but the packet
                # never becomes an enqueue candidate — the sender learns
                # via silence, then RTO / SACK / go-back-N
                ln_down = nic_down[lane_src]
                bh_nic = (jnp.sum(sel & ln_down)
                          + jnp.sum(probe_valid & ln_down)
                          ).astype(jnp.int32)
                sel = sel & (~ln_down)
                probe_valid = probe_valid & (~ln_down)

            # ---- 2. fused ring service + enqueue (kernels 1 + 2) -------
            if DP > 1:
                # Inline jnp: the inter-pod hop — each pod pops its own
                # ring rows' heads and the [~Q x 7 scalar] head fields
                # cross pods in one all_gather; on enqueue each pod
                # writes only the ring rows it owns (the accept /
                # position math is replicated, so every pod agrees).
                qs = st.qsize[:Q]
                if pfc:
                    has = (qs > 0) & (~paused_row)
                else:
                    has = qs > 0
                qhead_pad = jnp.pad(st.qhead, (0, QR - (Q + 1)))
                hidx_l = jax.lax.dynamic_slice_in_dim(
                    qhead_pad, qoff, QRL) % cap
                pop_l = PktQ(*[f[jnp.arange(QRL), hidx_l]
                               for f in st.q])
                pop = PktQ(*[a[:Q] for a in gath(pop_l)])
                has = has & (pop.ready <= t)
                if row_duty is not None:
                    has = has & row_duty
                residual = jnp.maximum(qs - 1, 0).astype(jnp.float32)
                frac = jnp.clip((residual - kmin_p)
                                / jnp.maximum(kmax_p - kmin_p, 1e-9),
                                0.0, 1.0)
                dither = jnp.abs(jnp.sin(
                    t.astype(jnp.float32) * 12.9898
                    + qrows.astype(jnp.float32) * 78.233))
                mark = has & (~pop.probe) & (frac > dither * 0.999)
                ecn_out = pop.ecn | mark
                served = has.astype(jnp.int32)
                qhead = st.qhead.at[:Q].add(served)
                qsize = st.qsize.at[:Q].add(-served)
                # chaos blackhole/corruption — replicated math, identical
                # on every pod (see serve_enqueue_core for semantics)
                surv = has
                bh_add = jnp.zeros((), jnp.int32)
                cor_add = jnp.zeros((), jnp.int32)
                if row_down is not None:
                    bh_add = jnp.sum(has & row_down).astype(jnp.int32)
                    surv = surv & (~row_down)
                if row_cor_p is not None:
                    u = fault_u01(fseed, qrows, ti, pop.psn)
                    corrupt = surv & (~pop.probe) & (u < row_cor_p)
                    cor_add = jnp.sum(corrupt).astype(jnp.int32)
                    surv = surv & (~corrupt)
                fclip = jnp.clip(pop.flow, 0, N - 1)
                pop_bytes = wire_bytes(pop.flow, pop.psn, pop.probe)
                adv_tgt = jnp.where(
                    is_up_row, TS + spine_of_row * T + dst_tor[fclip],
                    2 * TS + dst[fclip])[:2 * TS]
                adv_valid = surv[:2 * TS]
                cand_qid = jnp.concatenate([adv_tgt, inj_q, inj_qp])
                cand_valid = jnp.concatenate(
                    [adv_valid, sel, probe_valid])
                now_l = jnp.full((L,), now, jnp.float32)
                zb, ob = jnp.zeros((L,), bool), jnp.ones((L,), bool)
                cand = PktQ(
                    flow=jnp.concatenate(
                        [pop.flow[:2 * TS], lane_flow, lane_flow]),
                    psn=jnp.concatenate(
                        [pop.psn[:2 * TS], tx.psn, probe_tx.psn]),
                    ts=jnp.concatenate(
                        [pop.ts[:2 * TS], now_l, now_l]),
                    probe=jnp.concatenate(
                        [pop.probe[:2 * TS], zb, ob]),
                    ecn=jnp.concatenate([ecn_out[:2 * TS], zb, zb]),
                    ent=jnp.concatenate(
                        [pop.ent[:2 * TS], ent, ent_probe]),
                    ready=jnp.full((2 * TS + 2 * L,), 0, jnp.int32)
                    + t + 1 + K,
                    spine=jnp.concatenate(
                        [pop.spine[:2 * TS], spine, spine_p]))
                cand_bytes = jnp.concatenate([
                    pop_bytes[:2 * TS],
                    wire_bytes(lane_flow, tx.psn, zb),
                    wire_bytes(lane_flow, probe_tx.psn, ob)])
                M = 2 * TS + 2 * L
                if M <= 256:
                    tril = jnp.tril(jnp.ones((M, M), bool), k=-1)
                    same_q = cand_qid[:, None] == cand_qid[None, :]

                    def rank_among(flag):
                        return jnp.sum(same_q & flag[None, :] & tril,
                                       axis=1).astype(jnp.int32)
                else:
                    def rank_among(flag):
                        return _rank_in_queue(cand_qid, flag, Q)
                rank_v = rank_among(cand_valid)
                occ = qsize[cand_qid] + rank_v
                dropped = cand_valid & (
                    ((~cand.probe) & (occ >= data_drop_pkts))
                    | (occ >= hard_pkts))
                accept = cand_valid & (~dropped)
                rank_a = rank_among(accept)
                pos = (qhead[cand_qid] + qsize[cand_qid] + rank_a) % cap
                ownq = accept & (cand_qid >= qoff) \
                    & (cand_qid < qoff + QRL)
                flat_idx = jnp.where(
                    ownq, (cand_qid - qoff) * cap + pos, QRL * cap)

                def _wrow(f, v):
                    flat = f.reshape(-1)
                    pad1 = jnp.zeros((1,), f.dtype)
                    out = jnp.concatenate([flat, pad1], 0).at[flat_idx]
                    return out.set(v)[:QRL * cap].reshape(QRL, cap)

                q = PktQ(*[_wrow(f, v) for f, v in zip(st.q, cand)])
                added = jax.ops.segment_sum(
                    accept.astype(jnp.int32),
                    jnp.where(accept, cand_qid, Q), num_segments=Q + 1)
                qsize = (qsize + added).at[Q].set(0)
                qhead = qhead.at[Q].set(0)
                drops = st.drops + jnp.sum(dropped).astype(jnp.int32)
            else:
                (q, qhead, qsize, pop, has, surv, ecn_out, pop_bytes,
                 cand_qid, cand_bytes, accept, drops_add, bh_add,
                 cor_add) = _serve(
                    serve_enqueue_core,
                    (st.q, st.qhead, st.qsize, paused_row, dst,
                     dst_tor, total_pkts, tail_b, lane_flow, tx.psn,
                     probe_tx.psn, ent, ent_probe, spine, spine_p, sel,
                     probe_valid, inj_q, inj_qp, row_down, row_duty,
                     row_cor_p, fseed, t))
                fclip = jnp.clip(pop.flow, 0, N - 1)
                drops = st.drops + drops_add

            # ---- 3. deliveries -> per-flow receivers (one host = one q)
            # (surv, not has: blackholed/corrupted packets left their
            # buffer but never arrive)
            del_has = surv[2 * TS:]
            del_flow = fclip[2 * TS:]
            slot_del = (t + dflow[del_flow]) % H
            if DP > 1:
                # receiver + return-pipe state live on the flow-owner
                # pod: every pod walks the global delivery rows but
                # gathers / commits only the flows it owns (trash row
                # otherwise)
                own = del_has & (del_flow >= foff) \
                    & (del_flow < foff + NL)
                lrow = jnp.where(own, del_flow - foff, NL)
                rrows = _gather_rows(st.rcv, lrow, NL)
                commit, fidx, n_lanes = own, lrow, NL
            else:
                rrows = jax.tree.map(lambda a: a[del_flow], st.rcv)
                commit, fidx, n_lanes = del_has, del_flow, N
            rnew, sack = jax.vmap(
                lambda r, psn, sz, ecn, ent_, ts, pb: proto.on_data(
                    r, psn, sz, ecn, ent_, ts, pb, now))(
                rrows, pop.psn[2 * TS:], pop_bytes[2 * TS:],
                ecn_out[2 * TS:], pop.ent[2 * TS:],
                pop.ts[2 * TS:], pop.probe[2 * TS:])
            rnew = _bwhere(commit, rnew, rrows)
            rcv = _scatter_rows(st.rcv, rnew,
                                jnp.where(commit, fidx, n_lanes),
                                n_lanes)
            delivered = _scatter_add(
                st.delivered,
                jnp.where(del_has & (~pop.probe[2 * TS:]), del_flow, N),
                pop_bytes[2 * TS:], N)
            # ECN observability: marked data packets counted at host
            # delivery (outside the kernel cores, so identical across
            # every lane formulation and kernel backend; warp-safe —
            # skipped ticks deliver nothing)
            ecn_add = jnp.sum(del_has & ecn_out[2 * TS:]
                              & (~pop.probe[2 * TS:])).astype(jnp.int32)

            # write emitted messages into the return pipe at slot
            # t + D[flow]: each flow's ACK rides its own reverse path
            # (never the slot the transport stage cleared this tick:
            # 1 <= D[flow] <= H - 2)
            sack_valid = sack.valid & commit
            pipe = _scatter_pipe(pipe, sack._replace(valid=sack_valid),
                                 slot_del, fidx, sack_valid, H, n_lanes)

            # ---- 6b. PFC: per-ingress accounting + pause/resume masks ----
            # Ingress attribution is derivable per packet: a packet's port
            # at any switch follows from (flow src/dst, queue row, entropy),
            # so the counters are maintained incrementally without storing
            # a port field in the ring.  Accounting is per-packet WIRE
            # bytes: odd tail packets and 64B probes count their real
            # size, not a whole MTU (``events.Switch`` semantics).
            if pfc:
                # dequeues leaving a switch buffer
                f_up, f_sd, f_hd = (fclip[:TS], fclip[TS:2 * TS],
                                    fclip[2 * TS:])
                ing_host = _scatter_add(
                    st.ing_host, jnp.where(has[:TS], src[f_up], NH),
                    -pop_bytes[:TS], NH)
                sd_i = jnp.arange(TS, dtype=jnp.int32)
                sd_s = sd_i // T   # spine of spine_down row TS + s*T + t
                up_flat = st.ing_up.reshape(-1)
                up_flat = _scatter_add(
                    up_flat,
                    jnp.where(has[TS:2 * TS], src_tor[f_sd] * S + sd_s, TS),
                    -pop_bytes[TS:2 * TS], TS)
                # the spine that handed the packet down is the ring's
                # injection-time spine lane — re-deriving it from ECMP
                # would diverge once fault schedules make the candidate
                # masks time-varying
                pkt_spine = pop.spine[2 * TS:]
                hd_same = same_tor[f_hd]
                served_hd = has[2 * TS:]
                ing_host = _scatter_add(
                    ing_host,
                    jnp.where(served_hd & hd_same, src[f_hd], NH),
                    -pop_bytes[2 * TS:], NH)
                sd_flat = st.ing_sd.reshape(-1)
                sd_flat = _scatter_add(
                    sd_flat,
                    jnp.where(served_hd & (~hd_same),
                              pkt_spine * T + host_tor, TS),
                    -pop_bytes[2 * TS:], TS)
                # enqueues entering a switch buffer
                up_i = jnp.arange(TS, dtype=jnp.int32)  # t*S+s of source row
                up_flat = _scatter_add(
                    up_flat, jnp.where(accept[:TS], up_i, TS),
                    cand_bytes[:TS], TS)
                sd_flat = _scatter_add(
                    sd_flat, jnp.where(accept[TS:2 * TS], sd_i, TS),
                    cand_bytes[TS:2 * TS], TS)
                acc_data = accept[2 * TS:2 * TS + L]
                acc_probe = accept[2 * TS + L:]
                ing_host = _scatter_add(
                    ing_host, jnp.where(acc_data, lane_src, NH),
                    cand_bytes[2 * TS:2 * TS + L], NH)
                ing_host = _scatter_add(
                    ing_host, jnp.where(acc_probe, lane_src, NH),
                    cand_bytes[2 * TS + L:], NH)
                ing_sd = sd_flat.reshape(S, T)
                ing_up = up_flat.reshape(T, S)

                # byte-accurate shared-buffer occupancy (served bytes out,
                # accepted bytes in) for the dynamic threshold
                qbytes = st.qbytes.at[:Q].add(
                    -jnp.where(has, pop_bytes, 0.0))
                add_b = jax.ops.segment_sum(
                    jnp.where(accept, cand_bytes, 0.0),
                    jnp.where(accept, cand_qid, Q), num_segments=Q + 1)
                qbytes = (qbytes + add_b).at[Q].set(0.0)
                qsz_b = qbytes[:Q]
                tor_occ = (qsz_b[:TS].reshape(T, S).sum(1)
                           + qsz_b[2 * TS:].reshape(T, HPT).sum(1))
                spine_occ = qsz_b[TS:2 * TS].reshape(S, T).sum(1)
                a = cfg.pfc_alpha
                xoff_tor = a * jnp.maximum(buffer_b - tor_occ, 0.0) / (1 + a)
                xoff_spine = a * jnp.maximum(buffer_b - spine_occ, 0.0) \
                    / (1 + a)

                # the gate chains on the switch's DECISION state; the
                # effective (upstream) state lags it by the pause-frame
                # propagation delay via the pfc_line ring
                paused_nic = pfc_gate(st.paused_nic, ing_host,
                                      xoff_tor[host_tor], cfg.pfc_xon_frac)
                paused_sd = pfc_gate(st.paused_sd, ing_sd,
                                     xoff_tor[None, :], cfg.pfc_xon_frac)
                paused_up = pfc_gate(st.paused_up, ing_up,
                                     xoff_spine[None, :], cfg.pfc_xon_frac)
                pauses = st.pauses + (
                    jnp.sum(paused_nic & ~st.paused_nic)
                    + jnp.sum(paused_sd & ~st.paused_sd)
                    + jnp.sum(paused_up & ~st.paused_up)).astype(jnp.int32)
                if PD > 0:
                    dec = jnp.concatenate(
                        [paused_nic, paused_sd.reshape(-1),
                         paused_up.reshape(-1)])
                    pfc_line = st.pfc_line.at[t % PD].set(dec)
                else:
                    pfc_line = st.pfc_line
            else:
                qbytes = st.qbytes
                ing_host, ing_sd, ing_up = (st.ing_host, st.ing_sd,
                                            st.ing_up)
                paused_nic, paused_sd, paused_up = (
                    st.paused_nic, st.paused_sd, st.paused_up)
                pfc_line = st.pfc_line
                pauses = st.pauses

            # ---- 7. completion + metrics --------------------------------
            if DP > 1:
                done = jax.lax.all_gather(
                    jax.vmap(proto.done)(flows), "pod", tiled=True)
            elif A:
                # done lanes update in place from the core's per-lane
                # done bits (see active_trans_core)
                done = _set_rows(
                    done_prev, jnp.where(lane_ok, act_idx, N),
                    done_lane, N)
            else:
                done = jax.vmap(proto.done)(flows)
            done_tick = jnp.where(done & (st.done_tick < 0),
                                  t.astype(jnp.int32), st.done_tick)

            # message completion: all sub-flows done; newly-completed
            # messages decrement their children's pending-dep counters
            # (the children become sendable NEXT tick, step 0 above)
            undone = jax.ops.segment_sum((~done).astype(jnp.int32),
                                         dep.msg_of_flow,
                                         num_segments=n_msgs)
            msg_done = undone == 0
            newly = msg_done & (~st.msg_done)
            if n_edges > 0:
                dec = jax.ops.segment_sum(
                    newly[dep.edge_parent].astype(jnp.int32),
                    dep.edge_child, num_segments=n_msgs)
                pending = st.pending - dec
            else:
                pending = st.pending
            msg_done_tick = jnp.where(newly, t.astype(jnp.int32),
                                      st.msg_done_tick)
            g_undone = jax.ops.segment_sum((~msg_done).astype(jnp.int32),
                                           dep.group_of_msg,
                                           num_segments=n_groups)
            group_done_tick = jnp.where(
                (g_undone == 0) & (st.group_done_tick < 0),
                t.astype(jnp.int32), st.group_done_tick)

            # chaos observability: accepted data injections per target
            # row (the entropy-shift gates read this) + per-flap-window
            # retransmit attribution.  Both are exact on warp runs:
            # skipped ticks inject nothing.
            acc_data_l = accept[2 * TS:2 * TS + L]
            tx_rows = st.tx_rows.at[
                jnp.where(acc_data_l, inj_q, Q)].add(1)
            if FW > 0:
                in_win = (fd.win_t0 <= ti) \
                    & (ti < fd.win_t1 + 2 * rto_ticks)
                win_retx = st.win_retx + jnp.where(in_win, rtx_n, 0)
            else:
                win_retx = st.win_retx

            new_st = FabricState(
                flows=flows, rcv=rcv, q=q, qhead=qhead, qsize=qsize,
                pipe=pipe, obl_rr=obl_rr, drops=drops, delivered=delivered,
                done_tick=done_tick, qbytes=qbytes, ing_host=ing_host,
                ing_sd=ing_sd, ing_up=ing_up, paused_nic=paused_nic,
                paused_sd=paused_sd, paused_up=paused_up,
                pfc_line=pfc_line, pauses=pauses,
                pending=pending, msg_done=msg_done,
                msg_release_tick=msg_release_tick,
                msg_done_tick=msg_done_tick,
                group_done_tick=group_done_tick,
                act_overflow=st.act_overflow + overflow,
                ecn_marks=st.ecn_marks + ecn_add,
                # post-enqueue depth max; identity on warp-skipped ticks
                qdepth_hi=jnp.maximum(st.qdepth_hi, qsize),
                blackholed=st.blackholed + bh_add + bh_nic,
                corrupt_drops=st.corrupt_drops + cor_add,
                tx_rows=tx_rows, win_retx=win_retx)
            return new_st, jnp.any(can_tx)

        def snapshot(st: FabricState) -> dict:
            """Per-tick trace row, derived purely from state (so dense and
            decimated traces sample the identical quantities)."""
            done = jax.vmap(proto.done)(st.flows)
            return {
                "qsize": st.qsize[:Q],
                "drops_trace": st.drops,
                "done": jnp.sum(done).astype(jnp.int32),
                "cwnd_mean": jnp.mean(jax.vmap(proto.cong_pkts)(st.flows)),
                "delivered": st.delivered,
                "pauses_trace": st.pauses,
                "paused_ports": (jnp.sum(st.paused_nic)
                                 + jnp.sum(st.paused_sd)
                                 + jnp.sum(st.paused_up)).astype(jnp.int32),
            }

        def warp_target(st: FabricState, t):
            """Earliest tick > t that could be non-identity given an idle
            fabric: the soonest of (a) the first timer sweep at which some
            released flow's deadline has expired, (b) the first pacing
            release at which a window-open flow may send, (c) the next
            return-pipe slot holding an undelivered ACK/SACK/CNP, (d) the
            earliest departure-time-lane arrival of an in-flight packet
            (the per-hop pipeline's occupancy).  All are conservative
            lower bounds (floor rounding): an executed tick that turns out
            to be identity simply re-skips, so parity is exact and
            progress is >= 1 tick per trip.
            """
            if DP > 1:
                timer_ev, send_ev = gath(
                    jax.vmap(proto.next_event)(st.flows))
            else:
                timer_ev, send_ev = jax.vmap(proto.next_event)(st.flows)
            sendable = ((st.pending <= 0) & (arrival <= t))[dep.msg_of_flow]
            inf = jnp.float32(jnp.inf)
            timer_ev = jnp.where(sendable, timer_ev, inf)
            send_ev = jnp.where(sendable, send_ev, inf)

            def ev_tick(ev, half_early):
                e = jnp.min(ev)
                ratio = e / jnp.float32(tick_us) - half_early
                tk = jnp.where(
                    jnp.isfinite(e),
                    jnp.floor(jnp.minimum(
                        ratio, jnp.float32(n_ticks))).astype(jnp.int32),
                    jnp.int32(n_ticks))
                return jnp.maximum(t + 1, tk)

            every = cfg.timer_every
            t_timer = ev_tick(timer_ev, 0.0)
            t_timer = ((t_timer + every - 1) // every) * every
            # pacing tolerance mirrors next_packet: now + tick/2 >= ts
            t_send = ev_tick(send_ev, 0.5)
            slots = jnp.arange(H, dtype=jnp.int32)
            due = t + 1 + (slots - t - 1) % H
            if DP > 1:
                pipe_any = jnp.any(jax.lax.all_gather(
                    jnp.any(st.pipe.valid, axis=1), "pod"), axis=0)
            else:
                pipe_any = jnp.any(st.pipe.valid, axis=1)
            t_pipe = jnp.min(jnp.where(pipe_any, due, jnp.int32(n_ticks)))
            # in-flight pipeline occupancy: the earliest ready tick of any
            # nonempty unpaused queue's head (paused queues cannot change
            # state while the fabric is otherwise idle — the gate is a
            # fixed point absent serves/enqueues, and idle requires the
            # pause-frame delay line settled)
            if DP > 1:
                qhead_pad = jnp.pad(st.qhead, (0, QR - (Q + 1)))
                hidx_l = jax.lax.dynamic_slice_in_dim(
                    qhead_pad, qoff, QRL) % cap
                rdy = jax.lax.all_gather(
                    st.q.ready[jnp.arange(QRL), hidx_l], "pod",
                    tiled=True)[:Q]
            else:
                hidx = st.qhead[:Q] % cap
                rdy = st.q.ready[qrows, hidx]
            pending_q = st.qsize[:Q] > 0
            if pfc:
                dec_row = jnp.concatenate(
                    [st.paused_up.reshape(-1), st.paused_sd.reshape(-1),
                     jnp.zeros((NH,), bool)])
                pending_q = pending_q & (~dec_row)
            t_queue = jnp.maximum(t + 1, jnp.min(jnp.where(
                pending_q, rdy, jnp.int32(n_ticks))))
            # (e) the earliest future open-loop arrival of a dep-met
            # message (its release tick records at exactly that tick);
            # empty mask (all-arrival-0 traces) -> n_ticks, a no-op
            t_arr = jnp.maximum(t + 1, jnp.min(jnp.where(
                (st.pending <= 0) & (st.msg_release_tick < 0),
                arrival, jnp.int32(n_ticks))))
            tgt = jnp.minimum(jnp.minimum(t_timer, t_send),
                              jnp.minimum(t_pipe, t_queue))
            tgt = jnp.minimum(tgt, t_arr)
            if HAS_FAULTS:
                # (f) fault-schedule transitions are first-class wake
                # sources: a warp trip can never jump over a flap /
                # degrade / corruption boundary, so link state is
                # re-evaluated at every edge (docs/robustness.md)
                t_fault = jnp.maximum(t + 1, jnp.min(jnp.where(
                    fd.edges > t, fd.edges, jnp.int32(n_ticks))))
                tgt = jnp.minimum(tgt, t_fault)
            return jnp.minimum(tgt, jnp.int32(n_ticks))

        if cfg.time_warp:
            def trip(carry):
                t, st, trips = carry
                st, can_any = tick(st, t)
                # Idle <=> every future tick up to the warp target is a
                # provable no-op: no released flow offered a packet this
                # tick (send eligibility is time-independent between
                # timer/pacing/ack events), any queued packet is still in
                # flight on its link (warp_target wakes at the earliest
                # departure-lane arrival), no freshly-released message
                # still needs its release tick recorded, and the PFC
                # pause-frame delay line holds no in-flight transition.
                idle = ((~can_any)
                        & ~jnp.any((st.pending <= 0) & (arrival <= t)
                                   & (st.msg_release_tick < 0)))
                if pfc and PD > 0:
                    dec = jnp.concatenate(
                        [st.paused_nic, st.paused_sd.reshape(-1),
                         st.paused_up.reshape(-1)])
                    idle = idle & jnp.all(st.pfc_line == dec[None, :])
                t_next = jnp.where(idle, warp_target(st, t), t + 1)
                return t_next, st, trips + jnp.int32(1)

            end_t, final, trips = jax.lax.while_loop(
                lambda c: c[0] < n_ticks, trip,
                (jnp.int32(0), st0, jnp.int32(0)))
            return final, {"warp_trips": trips, "end_tick": end_t}

        if trace_every == 0:
            final = jax.lax.fori_loop(
                0, n_ticks, lambda t, st: tick(st, t)[0], st0)
            return final, {}

        k = trace_every
        n_blocks, rem = divmod(n_ticks, k)

        def block(st, b):
            st = jax.lax.fori_loop(
                0, k, lambda i, s: tick(s, b * k + i)[0], st)
            return st, snapshot(st)

        final, ys = jax.lax.scan(block, st0,
                                 jnp.arange(n_blocks, dtype=jnp.int32))
        if rem:  # the trace samples block ends; the summary carry is exact
            final = jax.lax.fori_loop(n_blocks * k, n_ticks,
                                      lambda t, s: tick(s, t)[0], final)
        return final, ys

    if DP > 1:
        # One shard_map around the whole program: the heavy state (queue
        # rings by switch-row block; flow/receiver/return-pipe by flow
        # block) lives partitioned for the entire scan, the small
        # per-queue/per-message vectors are computed replicated (identical
        # op order on every pod — bit-exact vs the unsharded program), and
        # the two explicit all_gather exchanges above are the only
        # cross-pod traffic.
        Pspec = jax.sharding.PartitionSpec
        mesh = compat.make_mesh((DP,), ("pod",))
        fl_s, rcv_s = jax.eval_shape(
            proto.init,
            jax.ShapeDtypeStruct((NL,), jnp.int32),
            jax.ShapeDtypeStruct((NL,), jnp.float32),
            jax.ShapeDtypeStruct((NL,), jnp.int32))
        pipe_s = jax.eval_shape(lambda: proto.empty_msgs(H, NL))
        rep = Pspec()
        st_spec = FabricState(
            flows=jax.tree.map(lambda _: Pspec("pod"), fl_s),
            rcv=jax.tree.map(lambda _: Pspec("pod"), rcv_s),
            q=PktQ(*([Pspec("pod")] * len(PktQ._fields))),
            qhead=rep, qsize=rep,
            pipe=jax.tree.map(lambda _: Pspec(None, "pod"), pipe_s),
            obl_rr=rep, drops=rep, delivered=rep, done_tick=rep,
            qbytes=rep, ing_host=rep, ing_sd=rep, ing_up=rep,
            paused_nic=rep, paused_sd=rep, paused_up=rep, pfc_line=rep,
            pauses=rep, pending=rep, msg_done=rep, msg_release_tick=rep,
            msg_done_tick=rep, group_done_tick=rep, act_overflow=rep,
            ecn_marks=rep, qdepth_hi=rep, blackholed=rep,
            corrupt_drops=rep, tx_rows=rep, win_retx=rep)
        m_spec = ({"warp_trips": rep, "end_tick": rep}
                  if cfg.time_warp else {})
        sharded = compat.shard_map(
            body, mesh=mesh, in_specs=(rep,) * 8,
            out_specs=(st_spec, m_spec), check_vma=False)

        def program(src, dst, total_pkts, tail_b, ent0, lb_code, arrival,
                    fd):
            return sharded(src, dst, total_pkts, tail_b, ent0, lb_code,
                           arrival, fd)
    else:
        program = body
    program.dims = dict(T=T, S=S, NH=NH, TS=TS, Q=Q, cap=cap, H=H,
                        K=K, D_same=D_same, D_cross=D_cross, PD=PD,
                        shard=DP, active_cap=A)
    return program


# --------------------------------------------------------------------------- #
# Program cache: build + jit once per static shape, reuse across run()/sweep()
# --------------------------------------------------------------------------- #

#: Cumulative count of fresh program builds (cache misses).  The regression
#: tests assert this does not grow when a same-shape scenario re-runs.
program_builds = 0

#: Cumulative count of jax TRACES of fabric program bodies (bumped by a
#: python side effect inside the body, which only runs while tracing).  A
#: cached program can still retrace when called with a new input shape —
#: e.g. a new batch size — so this is the regression hook for the
#: job-axis bucketing: bucketed job counts must reuse one trace.
program_traces = 0

_PROGRAM_CACHE: "OrderedDict[tuple, _Program]" = OrderedDict()
_PROGRAM_CACHE_MAX = 32  # LRU bound: compiled executables are not free


class _Program(NamedTuple):
    """One cached fabric program: the raw builder output plus its jitted
    single-run and vmapped-batch entry points (kept as stable callables so
    jax's own jit cache is hit instead of re-tracing every call)."""

    program: Callable
    jit_single: Callable
    jit_batch: Callable
    dims: dict


def _program_key(topo: FatTree, n_flows: int, n_ticks: int,
                 cfg: FabricConfig, dep: DepSpec) -> tuple:
    """Hashable fingerprint of everything `_make_program` closes over.

    ``lb_mode`` and ``roce_entropy_seed`` are *data* to the program (traced
    lb_code argument / host-computed ent0 array) and ``subflows`` is fully
    captured by the flow count + DepSpec, so all three are normalized out —
    sweeping them reuses one compiled program.
    """
    # The fault schedule is program DATA except for its entry counts:
    # shape_key is the static part (and an empty spec is the same program
    # as no spec at all), so same-shape chaos schedules share one compile.
    fkey = (cfg.faults.shape_key if cfg.faults is not None
            else (0, 0, 0, 0, 0, 0))
    norm = dataclasses.replace(
        cfg, lb_mode="adaptive", roce_entropy_seed=None, subflows=1,
        trace_every=0 if cfg.time_warp else cfg.trace_every,
        faults=None)
    dep_key = (dep.n_msgs, dep.n_groups,
               np.asarray(dep.msg_of_flow).tobytes(),
               np.asarray(dep.group_of_msg).tobytes(),
               np.asarray(dep.init_pending).tobytes(),
               np.asarray(dep.edge_parent).tobytes(),
               np.asarray(dep.edge_child).tobytes())
    return ((topo.n_tor, topo.hosts_per_tor, topo.n_spine, topo.dead_links),
            n_flows, n_ticks, norm, dep_key, fkey)


def _get_program(topo: FatTree, n_flows: int, n_ticks: int,
                 cfg: FabricConfig, dep: Optional[DepSpec] = None,
                 n_real: Optional[int] = None) -> _Program:
    """Cached (program, jitted entry points) for the given static dims."""
    if dep is None:
        dep = _trivial_dep(range(n_flows))
    key = _program_key(topo, n_flows, n_ticks, cfg, dep) + (n_real,)
    prog = _PROGRAM_CACHE.get(key)
    if prog is None:
        program = _make_program(topo, n_flows, n_ticks, cfg, dep,
                                n_real=n_real)
        # the batch axis vmaps the flow-array inputs; the fault schedule
        # is shared across the whole batch (in_axes=None broadcasts it)
        prog = _Program(program=program, jit_single=jax.jit(program),
                        jit_batch=jax.jit(jax.vmap(
                            program,
                            in_axes=(0, 0, 0, 0, 0, 0, 0, None))),
                        dims=program.dims)
        _PROGRAM_CACHE[key] = prog
        while len(_PROGRAM_CACHE) > _PROGRAM_CACHE_MAX:
            _PROGRAM_CACHE.popitem(last=False)
    else:
        _PROGRAM_CACHE.move_to_end(key)
    return prog


def clear_program_cache() -> None:
    """Drop all cached fabric programs (frees their jit caches too)."""
    _PROGRAM_CACHE.clear()


def _check_flows(flows, n_hosts: int) -> None:
    for s_, d_, _ in flows:
        if not (0 <= s_ < n_hosts and 0 <= d_ < n_hosts and s_ != d_):
            raise ValueError(f"bad flow endpoint (src={s_}, dst={d_}) for "
                             f"{n_hosts} hosts")


_UNSET = object()


def _flow_arrays(flows, cfg: FabricConfig, entropy_seed=_UNSET):
    """Host-side program inputs for one flow list.  ``entropy_seed``
    overrides ``cfg.roce_entropy_seed`` (sweeps vmap the seed axis, so the
    batch helper passes a per-entry seed against one shared cfg).

    Returns ``(src, dst, total_pkts, tail_bytes, ent0)`` — ``tail_bytes``
    is the wire size of each flow's final PSN (``ref.pkt_size`` odd-tail
    semantics: sub-MTU and non-MTU-multiple messages are first-class)."""
    if entropy_seed is _UNSET:
        entropy_seed = cfg.roce_entropy_seed
    mtu = cfg.net.mtu_bytes
    src = jnp.asarray([f[0] for f in flows], jnp.int32)
    dst = jnp.asarray([f[1] for f in flows], jnp.int32)
    npkts = [max(1, int(math.ceil(f[2] / mtu))) for f in flows]
    total_pkts = jnp.asarray(npkts, jnp.int32)
    tail_bytes = jnp.asarray(
        [max(1.0, float(f[2]) - (n - 1) * mtu)
         for f, n in zip(flows, npkts)], jnp.float32)
    if entropy_seed is not None:
        rng = random.Random(entropy_seed)
        ent0 = jnp.asarray([rng.randrange(1 << 16) for _ in flows],
                           jnp.int32)
    else:
        # per-flow pinned entropy for non-spray protocols (one QP each, the
        # analogue of the oracle's rng.randrange(1 << 16)); striped
        # sub-flows of one message get distinct draws via the flow index
        iota_n = jnp.arange(len(flows), dtype=jnp.int32)
        ent0 = ecmp_mix(src, dst, iota_n + jnp.int32(40503)) % (1 << 16)
    return src, dst, total_pkts, tail_bytes, ent0


def _arrival_array(messages) -> jax.Array:
    """Per-message earliest-launch ticks (i32[n_msgs], input order).

    ``arrival`` is optional on the message records (``_FlowMsg`` and
    ``workloads.Message`` both default it to 0), so legacy traces keep
    the closed-loop all-zero array."""
    return jnp.asarray([max(0, int(getattr(m, "arrival", 0)))
                        for m in messages], jnp.int32)


def _pad_flow_arrays(arrs, npad: int, n_hosts: int):
    """Pad program input arrays with ``npad`` inert flows.

    Pad flows have ``total_pkts == 0`` — both protocols initialise them
    done-at-t0 and they never produce a candidate packet — so the padded
    program is observable-identical to the unpadded one (the NIC
    arbitration modulus uses ``n_real``, not the padded count)."""
    src, dst, total_pkts, tail_bytes, ent0 = arrs
    z = jnp.zeros((npad,), jnp.int32)
    return (jnp.concatenate([src, z]),
            jnp.concatenate([dst, jnp.full((npad,), n_hosts - 1,
                                           jnp.int32)]),
            jnp.concatenate([total_pkts, z]),
            jnp.concatenate([tail_bytes, jnp.ones((npad,), jnp.float32)]),
            jnp.concatenate([ent0, z]))


def _pad_dep(dep: DepSpec, npad: int) -> DepSpec:
    """Extend a DepSpec with ``npad`` pad flows, each its own dep-free
    message in its own extra group (so no real message or group waits on,
    or is counted with, a pad)."""
    ar = np.arange(npad, dtype=np.int32)
    cat = lambda a, b: jnp.asarray(
        np.concatenate([np.asarray(a, np.int32), b.astype(np.int32)]))
    pad_ids = tuple(f"__shard_pad{i}" for i in range(npad))
    return DepSpec(
        n_msgs=dep.n_msgs + npad, n_groups=dep.n_groups + npad,
        msg_of_flow=cat(dep.msg_of_flow, dep.n_msgs + ar),
        group_of_msg=cat(dep.group_of_msg, dep.n_groups + ar),
        init_pending=cat(dep.init_pending, np.zeros(npad)),
        edge_parent=dep.edge_parent, edge_child=dep.edge_child,
        msg_ids=dep.msg_ids + pad_ids, group_ids=dep.group_ids + pad_ids)


def _shard_pad_inputs(flows, dep: DepSpec, arrs, cfg: FabricConfig,
                      n_hosts: int):
    """Pad the flow axis to a multiple of ``cfg.shard`` so the per-pod
    lane count is uniform.  Returns ``(arrs, dep_run, n_real)`` where
    ``n_real`` is None when no padding was needed."""
    d = int(cfg.shard)
    npad = (-len(flows)) % d
    if npad == 0:
        return arrs, dep, None
    return (_pad_flow_arrays(arrs, npad, n_hosts), _pad_dep(dep, npad),
            len(flows))


def _slice_fin(fin: dict, n: int, n_msgs: int, n_groups: int) -> dict:
    """Strip shard-pad entries from a :func:`_final_host` dict so the
    metrics layer only ever sees the caller's real flows/messages/groups."""
    out = dict(fin)
    for k, m in (("done_tick", n), ("delivered", n), ("retx", n),
                 ("rto_fires", n), ("sack_recoveries", n),
                 ("gbn_rewinds", n),
                 ("msg_done_tick", n_msgs), ("msg_release_tick", n_msgs),
                 ("group_done_tick", n_groups)):
        if k in fin:
            out[k] = fin[k][..., :m]
    return out


#: Final-state arrays the host-side metrics derive from — fetched in ONE
#: ``jax.device_get`` (the old per-scalar pulls were a device-sync storm
#: that dominated wall-clock at collective flow counts).
_FINAL_KEYS = ("done_tick", "msg_done_tick", "msg_release_tick",
               "group_done_tick", "drops", "pauses", "delivered",
               "act_overflow", "ecn_marks", "qdepth_hi", "blackholed",
               "corrupt_drops", "tx_rows", "win_retx")


def _final_host(finals) -> dict:
    """One host round-trip for every final-state array the metrics need
    (works on a vmapped batch state too: values keep their leading batch
    dim; slice per entry on the host)."""
    vals = jax.device_get(tuple(getattr(finals, k) for k in _FINAL_KEYS))
    return dict(zip(_FINAL_KEYS, vals))


def _us_or_none(ticks, ok, tick_us: float) -> list:
    """[tick * tick_us or None] rows from host arrays (vectorized; no
    per-element device access)."""
    us = np.asarray(ticks, dtype=np.float64) * tick_us
    return [float(v) if o else None
            for v, o in zip(us, np.asarray(ok, dtype=bool))]


def _finish_metrics(metrics: dict, fin: dict, cfg: FabricConfig,
                    dims: dict, dep: DepSpec) -> dict:
    """Attach host-side derived metrics for one run.

    ``fin`` is the :func:`_final_host` dict (one batch entry) of the final
    state.  ``fct_us`` is MESSAGE-level: release (deps met) to
    last-sub-flow completion — identical to the old per-flow FCT for
    deps-free single-sub-flow traces.  ``drops``/``pauses`` are the exact
    final-carry counters, independent of any (decimated or disabled)
    per-tick trace.
    """
    T, S, TS = dims["T"], dims["S"], dims["TS"]
    tick_us = cfg.net.mtu_serialize_us
    _, _, _, target_qdelay_us = _make_protocol(cfg)
    metrics["tick_us"] = tick_us
    metrics["trace_every"] = 0 if cfg.time_warp else cfg.trace_every
    metrics["target_qdelay_pkts"] = target_qdelay_us / tick_us
    dt = np.asarray(fin["done_tick"])
    metrics["done_tick"] = dt
    # +1: a message is complete when its last ACK lands, i.e. at tick end
    metrics["subflow_fct_us"] = _us_or_none(dt + 1, dt >= 0, tick_us)
    mdt = np.asarray(fin["msg_done_tick"])
    mrt = np.asarray(fin["msg_release_tick"])
    metrics["fct_us"] = _us_or_none(mdt + 1 - np.maximum(mrt, 0),
                                    mdt >= 0, tick_us)
    metrics["msg_release_us"] = _us_or_none(mrt, mrt >= 0, tick_us)
    metrics["msg_ids"] = dep.msg_ids
    # original group id per message (tenant attribution in summarize)
    gof = np.asarray(dep.group_of_msg)
    metrics["msg_group_ids"] = tuple(dep.group_ids[g] for g in gof)
    # exact summary counters from the final scan carry (satellite of the
    # event-horizon change: summaries stay exact when the trace is
    # decimated or off entirely)
    metrics["drops"] = int(fin["drops"])
    metrics["pauses"] = int(fin["pauses"])
    ov = int(np.asarray(fin["act_overflow"]).reshape(-1)[-1])
    if ov:
        raise RuntimeError(
            f"active_cap={dims.get('active_cap')} exceeded on {ov} tick(s) "
            f"— sendable flows beyond the cap would silently stall; raise "
            f"FabricConfig.active_cap (or set it to None)")
    metrics["delivered_final"] = np.asarray(fin["delivered"])
    # observability counters: exact final-carry scalars/vectors, available
    # at any trace decimation (incl. off) and under the warp scan
    metrics["ecn_marks"] = int(np.asarray(fin["ecn_marks"]).reshape(-1)[-1])
    metrics["qdepth_hi_pkts"] = np.asarray(fin["qdepth_hi"])[:dims["Q"]]
    # recovery + chaos counters: UNIFORM keys, zero-filled where a
    # protocol or backend lacks the underlying counter, so dashboards and
    # the bench schema never KeyError (docs/robustness.md)
    metrics["retransmits"] = (int(np.sum(np.asarray(fin["retx"])))
                              if "retx" in fin else 0)
    for k in ("rto_fires", "sack_recoveries", "gbn_rewinds"):
        metrics[k] = int(np.sum(np.asarray(fin[k]))) if k in fin else 0
    for k_out, k_in in (("blackholed_pkts", "blackholed"),
                        ("corrupt_drops", "corrupt_drops")):
        metrics[k_out] = (int(np.asarray(fin[k_in]).reshape(-1)[-1])
                          if k_in in fin else 0)
    if "tx_rows" in fin:
        # accepted data injections per queue row (entropy-shift gates)
        metrics["tx_rows_pkts"] = np.asarray(fin["tx_rows"])[:dims["Q"]]
    if "win_retx" in fin:
        # retransmit attempts attributed to each flap window (+2 RTO)
        metrics["win_retx"] = np.asarray(fin["win_retx"])
    # Collective (group) metrics only for traces that actually carry
    # trace structure (dependency edges or several groups) — the events
    # backend likewise only reports group keys for TraceRunner-scheduled
    # traces, and the summary-dict contract is that both backends return
    # the same keys per scenario.
    if int(dep.edge_parent.shape[0]) > 0 or dep.n_groups > 1:
        gdt = np.asarray(fin["group_done_tick"])
        metrics["group_ids"] = dep.group_ids
        metrics["group_done_us"] = _us_or_none(gdt + 1, gdt >= 0, tick_us)
    metrics["queue_ids"] = {
        "tor_up": lambda t_, s_: t_ * S + s_,
        "spine_down": lambda s_, t_: TS + s_ * T + t_,
        "host_down": lambda h_: 2 * TS + h_,
    }
    return metrics


def run_fabric_trace(topo: FatTree, messages, n_ticks: int,
                     cfg: FabricConfig = FabricConfig()):
    """Simulate a dependency-edged message trace on the jitted fat-tree.

    ``messages`` is a sequence of records with ``mid/src/dst/size/deps/
    group`` attributes (``workloads.Message``); ``cfg.subflows`` stripes
    each message over that many single-QP sub-flows.  Returns
    (final_state, metrics): message/group completion metrics always, the
    per-tick trace per ``cfg.trace_every`` (events-only when 0 or when
    ``cfg.time_warp`` collapses dead intervals).

    Programs are cached on the static dims — repeated same-shape calls
    (benchmark seed loops, parity pairs) trace and compile exactly once.
    """
    flows, dep = expand_messages(messages, cfg.subflows)
    _check_flows(flows, topo.n_hosts)
    if cfg.faults is not None:
        validate_faults(cfg.faults, topo)
    fd = build_fault_data(cfg.faults, topo.n_tor, topo.n_spine,
                          topo.hosts_per_tor)
    arrs = _flow_arrays(flows, cfg)
    arrival = _arrival_array(messages)
    dep_run, n_real = dep, None
    if int(cfg.shard) > 1:
        arrs, dep_run, n_real = _shard_pad_inputs(
            flows, dep, arrs, cfg, topo.n_hosts)
        arrival = jnp.concatenate([
            arrival, jnp.zeros((dep_run.n_msgs - dep.n_msgs,), jnp.int32)])
    src, dst, total_pkts, tails, ent0 = arrs
    prog = _get_program(topo, int(src.shape[0]), n_ticks, cfg, dep_run,
                        n_real=n_real)
    lb = jnp.int32(LB_MODES.index(cfg.lb_mode))
    final, metrics = prog.jit_single(src, dst, total_pkts, tails, ent0, lb,
                                     arrival, fd)
    proto, _, _, _ = _make_protocol(cfg)
    fin = _final_host(final)
    fin["retx"] = jax.device_get(proto.stat_retx(final.flows))
    fin.update(jax.device_get(proto.stat_recovery(final.flows)))
    if n_real is not None:
        fin = _slice_fin(fin, n_real, dep.n_msgs, dep.n_groups)
    metrics = _finish_metrics(dict(metrics), fin, cfg, prog.dims, dep)
    return final, metrics


def run_fabric(topo: FatTree,
               flows: Sequence[Tuple[int, int, float]],
               n_ticks: int,
               cfg: FabricConfig = FabricConfig()):
    """Simulate ``flows`` = [(src_host, dst_host, msg_bytes), ...] on a
    fat-tree for ``n_ticks``; returns (final_state, per-tick metrics).

    The deps-free special case of :func:`run_fabric_trace` (one message per
    flow, striped if ``cfg.subflows > 1``)."""
    msgs = [_FlowMsg(mid=i, src=s, dst=d, size=b)
            for i, (s, d, b) in enumerate(flows)]
    return run_fabric_trace(topo, msgs, n_ticks, cfg)


def _job_bucket(b: int) -> int:
    """Next power-of-two bucket for the vmapped job axis (1, 2, 4, 8...).

    Batch sizes inside one bucket present identical input shapes to the
    cached program's ``jit_batch`` entry point, so they share a single
    trace/compile."""
    return 1 << (int(b) - 1).bit_length()


def run_fabric_trace_batch(topo: FatTree, messages_batch, n_ticks: int,
                           cfg: FabricConfig = FabricConfig(),
                           lb_modes: Optional[Sequence[str]] = None,
                           entropy_seeds: Optional[Sequence] = None):
    """vmap a batch of same-structure message traces through ONE jitted
    fabric program.

    All batch entries must share the dependency structure (message count,
    deps, groups, sub-flow fan-out) and topology; everything that is mere
    *data* to the program may vary per entry: src/dst/size patterns,
    ``lb_modes`` (per-entry STrack spray mode) and ``entropy_seeds``
    (per-entry QP-entropy seed, RoCEv2) — the config axes ``sweep()``
    fans out.  Returns (stacked_final_state, [metrics_dict_per_entry]).

    The job axis is bucket-padded to the next power of two (pad entries
    replay entry 0 and are dropped from the results), so nearby job counts
    share ONE jit trace of the cached program instead of re-tracing per
    batch size — the multi-tenant compile-time lever.  The returned
    stacked final state keeps the padded leading dim."""
    if not messages_batch:
        raise ValueError("need at least one message trace")
    if int(cfg.shard) > 1:
        raise ValueError(
            "cfg.shard > 1 builds one shard_map program over the device "
            "mesh; vmapped batches are unsupported — loop "
            "run_fabric_trace instead")
    B = len(messages_batch)
    if lb_modes is None:
        lb_modes = [cfg.lb_mode] * B
    if entropy_seeds is None:
        entropy_seeds = [cfg.roce_entropy_seed] * B
    if len(lb_modes) != B or len(entropy_seeds) != B:
        raise ValueError(
            f"lb_modes/entropy_seeds must match the batch: got "
            f"{len(lb_modes)}/{len(entropy_seeds)} for {B} traces")
    for m in lb_modes:
        if m not in LB_MODES:
            raise ValueError(f"unknown lb_mode {m!r}; "
                             f"expected one of {LB_MODES}")
    expanded = [expand_messages(ms, cfg.subflows) for ms in messages_batch]
    dep = expanded[0][1]
    for i, (_, d) in enumerate(expanded[1:], start=1):
        if int(d.msg_of_flow.shape[0]) != int(dep.msg_of_flow.shape[0]):
            raise ValueError(
                f"batch entry {i} has {int(d.msg_of_flow.shape[0])} "
                f"sub-flows, entry 0 has {int(dep.msg_of_flow.shape[0])}")
        same_deps = (
            d.edge_parent.shape == dep.edge_parent.shape
            and bool(jnp.all(d.edge_parent == dep.edge_parent))
            and bool(jnp.all(d.edge_child == dep.edge_child))
            and bool(jnp.all(d.group_of_msg == dep.group_of_msg)))
        if not same_deps:
            raise ValueError(
                f"batch entry {i} has a different dependency/group "
                f"structure than entry 0 — the whole batch runs under "
                f"entry 0's static DepSpec, so structures must match")
    if cfg.faults is not None:
        validate_faults(cfg.faults, topo)
    fd = build_fault_data(cfg.faults, topo.n_tor, topo.n_spine,
                          topo.hosts_per_tor)
    arrs = []
    arrivals = []
    for (flows, _), seed, msgs in zip(expanded, entropy_seeds,
                                      messages_batch):
        _check_flows(flows, topo.n_hosts)
        arrs.append(_flow_arrays(flows, cfg, entropy_seed=seed))
        arrivals.append(_arrival_array(msgs))
    lb_codes = [LB_MODES.index(m) for m in lb_modes]
    BP = _job_bucket(B)
    if BP > B:
        arrs = arrs + [arrs[0]] * (BP - B)
        arrivals = arrivals + [arrivals[0]] * (BP - B)
        lb_codes = lb_codes + [lb_codes[0]] * (BP - B)
    srcs = jnp.stack([a[0] for a in arrs])
    dsts = jnp.stack([a[1] for a in arrs])
    pkts = jnp.stack([a[2] for a in arrs])
    tails = jnp.stack([a[3] for a in arrs])
    ents = jnp.stack([a[4] for a in arrs])
    arrv = jnp.stack(arrivals)
    lbs = jnp.asarray(lb_codes, jnp.int32)
    prog = _get_program(topo, int(srcs.shape[1]), n_ticks, cfg, dep)
    finals, stacked = prog.jit_batch(srcs, dsts, pkts, tails, ents, lbs,
                                     arrv, fd)
    # one transfer for the finals + one for any stacked trace (the old
    # per-entry gather re-pulled the full batch B times)
    proto, _, _, _ = _make_protocol(cfg)
    fin_all = _final_host(finals)
    fin_all["retx"] = jax.device_get(proto.stat_retx(finals.flows))
    fin_all.update(jax.device_get(proto.stat_recovery(finals.flows)))
    stacked = jax.device_get(dict(stacked))
    per_entry = []
    for i in range(B):
        m = {k: v[i] for k, v in stacked.items()}
        fin_i = {k: v[i] for k, v in fin_all.items()}
        per_entry.append(_finish_metrics(m, fin_i, cfg, prog.dims, dep))
    return finals, per_entry


def run_fabric_batch(topo: FatTree,
                     flows_batch: Sequence[Sequence[Tuple[int, int, float]]],
                     n_ticks: int,
                     cfg: FabricConfig = FabricConfig()):
    """vmap a batch of same-shape flow lists (e.g. seeds of one workload)
    through ONE jitted fabric program (deps-free special case)."""
    sizes = {len(fl) for fl in flows_batch}
    if len(sizes) != 1:
        raise ValueError(f"flow lists must be same-shape, got sizes {sizes}")
    msgs_batch = [[_FlowMsg(mid=i, src=s, dst=d, size=b)
                   for i, (s, d, b) in enumerate(fl)] for fl in flows_batch]
    return run_fabric_trace_batch(topo, msgs_batch, n_ticks, cfg)


def summarize(metrics: dict) -> dict:
    """Event-oracle-style summary (max/avg FCT, unfinished, drops, pauses).

    Keys match ``workloads._summarize_sim`` so fabric and oracle results are
    directly comparable; ``pauses`` counts PFC xoff events (0 when PFC is
    off or the protocol runs lossy).  When the trace carries group
    structure, the TraceRunner-style collective keys (``group_fct`` /
    ``max_collective_time`` / ``finished_groups`` / ``total_groups``) ride
    along, keyed by the caller's original group ids.
    """
    fcts = [f for f in metrics["fct_us"] if f is not None]
    # drops/pauses are exact final-carry scalars since the trace became
    # opt-in; reshape(-1)[-1] also accepts a legacy per-tick array
    out = {
        "max_fct": max(fcts) if fcts else float("nan"),
        "avg_fct": sum(fcts) / len(fcts) if fcts else float("nan"),
        "unfinished": sum(1 for f in metrics["fct_us"] if f is None),
        "drops": int(np.asarray(metrics["drops"]).reshape(-1)[-1]),
        "pauses": int(np.asarray(metrics["pauses"]).reshape(-1)[-1]),
    }
    # observatory counters (absent on legacy/partial metrics dicts)
    if "ecn_marks" in metrics:
        out["ecn_marks"] = int(metrics["ecn_marks"])
    # recovery + chaos counters: uniformly present and zero-filled across
    # both protocols and backends — never a KeyError downstream
    for k in ("retransmits", "rto_fires", "sack_recoveries",
              "gbn_rewinds", "blackholed_pkts", "corrupt_drops"):
        out[k] = int(metrics.get(k, 0))
    # chaos attribution vectors (fabric backend only): accepted data
    # injections per queue row (entropy-shift gates) and retransmit
    # attempts attributed to each flap window (+2 RTO).  Tuples, not
    # arrays: summary dicts must stay ==-comparable and JSON-friendly.
    txr = metrics.get("tx_rows_pkts")
    if txr is not None:
        out["tx_rows_pkts"] = tuple(int(v)
                                    for v in np.asarray(txr).reshape(-1))
    wr = metrics.get("win_retx")
    if wr is not None and np.asarray(wr).size:
        out["win_retx"] = tuple(int(v)
                                for v in np.asarray(wr).reshape(-1))
    qhi = metrics.get("qdepth_hi_pkts")
    if qhi is not None:
        qhi = np.asarray(qhi)
        out["qdepth_max_pkts"] = int(qhi.max()) if qhi.size else 0
        out["qdepth_p99_pkts"] = (float(np.percentile(qhi, 99))
                                  if qhi.size else 0.0)
    gd = metrics.get("group_done_us")
    if gd is not None:
        gids = metrics.get("group_ids", tuple(range(len(gd))))
        group_fct = {g: t for g, t in zip(gids, gd) if t is not None}
        out["group_fct"] = group_fct
        out["max_collective_time"] = (max(group_fct.values())
                                      if group_fct else float("nan"))
        out["finished_groups"] = len(group_fct)
        out["total_groups"] = len(gd)
    # per-tenant (per original group id) FCT attribution: percentiles over
    # the message-level FCTs of each group
    mgids = metrics.get("msg_group_ids")
    if mgids is not None:
        by_g: dict = {}
        for g, f in zip(mgids, metrics["fct_us"]):
            by_g.setdefault(g, []).append(f)
        tenant = {}
        for g, fs in by_g.items():
            done = [f for f in fs if f is not None]
            row = {"count": len(fs),
                   "unfinished": len(fs) - len(done)}
            if done:
                arr = np.asarray(done, dtype=np.float64)
                row.update(p50=float(np.percentile(arr, 50)),
                           p99=float(np.percentile(arr, 99)),
                           avg=float(arr.mean()), max=float(arr.max()))
            else:
                row.update(p50=float("nan"), p99=float("nan"),
                           avg=float("nan"), max=float("nan"))
            tenant[g] = row
        out["tenant_fct"] = tenant
    return out
