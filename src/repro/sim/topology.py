"""2-tier fat-tree (Clos) topology with ECMP, oversubscription, link failures.

Matches the paper's evaluation fabric (Section 4.2): hosts -> ToR -> spine,
all links the same speed; oversubscription trims spine count; asymmetry
disables chosen ToR-spine links.  Path selection is ECMP: a deterministic
hash of (src, dst, entropy) over the *live* uplinks.

This Python model is the shared ground truth for both simulator backends:
``events.py`` consumes it directly, and ``fabric.py`` array-izes it
(``ArrayTopo.from_fat_tree``) with a bit-exact jnp mirror of ``_mix``.
"""
from __future__ import annotations

import dataclasses


def _mix(a: int, b: int, c: int) -> int:
    """Deterministic 32-bit hash mix (Knuth multiplicative + xors)."""
    h = (a * 2654435761) & 0xFFFFFFFF
    h ^= (b * 2246822519) & 0xFFFFFFFF
    h = (h * 3266489917) & 0xFFFFFFFF
    h ^= (c * 668265263) & 0xFFFFFFFF
    h = (h * 374761393) & 0xFFFFFFFF
    return (h >> 8) ^ (h & 0xFF)


@dataclasses.dataclass
class FatTree:
    n_tor: int = 8
    hosts_per_tor: int = 8
    n_spine: int = 8                 # == hosts_per_tor -> full bisection
    dead_links: frozenset = frozenset()  # {(tor, spine), ...}

    def __post_init__(self):
        self.n_hosts = self.n_tor * self.hosts_per_tor
        # live uplinks per ToR (ECMP next-hop candidates)
        self.live_up = [
            [s for s in range(self.n_spine) if (t, s) not in self.dead_links]
            for t in range(self.n_tor)
        ]
        for t, ups in enumerate(self.live_up):
            if not ups:
                raise ValueError(f"ToR {t} has no live uplinks")

    @property
    def oversubscription(self) -> float:
        return self.hosts_per_tor / self.n_spine

    def tor_of(self, host: int) -> int:
        return host // self.hosts_per_tor

    def ecmp_spine(self, src: int, dst: int, entropy: int) -> int:
        """ECMP: hash (src, dst, entropy) onto a live uplink of src's ToR."""
        tor = self.tor_of(src)
        ups = self.live_up[tor]
        return ups[_mix(src, dst, entropy) % len(ups)]

    def same_tor(self, src: int, dst: int) -> bool:
        return self.tor_of(src) == self.tor_of(dst)


def full_bisection(n_tor: int, hosts_per_tor: int) -> FatTree:
    return FatTree(n_tor=n_tor, hosts_per_tor=hosts_per_tor,
                   n_spine=hosts_per_tor)


def oversubscribed(n_tor: int, hosts_per_tor: int, ratio: int) -> FatTree:
    assert hosts_per_tor % ratio == 0
    return FatTree(n_tor=n_tor, hosts_per_tor=hosts_per_tor,
                   n_spine=hosts_per_tor // ratio)


def with_link_failures(base: FatTree, n_failed: int, n_tors_affected: int,
                       seed: int = 0) -> FatTree:
    """Disable ``n_failed`` ToR-spine links spread over ``n_tors_affected``
    ToRs (paper: 16 ToRs, 64 or 256 links)."""
    import random
    rng = random.Random(seed)
    tors = rng.sample(range(base.n_tor), min(n_tors_affected, base.n_tor))
    per_tor = max(1, n_failed // max(1, len(tors)))
    dead = set()
    for t in tors:
        spines = rng.sample(range(base.n_spine),
                            min(per_tor, base.n_spine - 1))
        dead.update((t, s) for s in spines)
    return FatTree(n_tor=base.n_tor, hosts_per_tor=base.hosts_per_tor,
                   n_spine=base.n_spine, dead_links=frozenset(dead))
