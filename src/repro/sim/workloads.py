"""Workload generators driving the simulators (Section 4.2/4.3).

* permutation — random src->dst pairing; every host sends one and receives
  one message (the load-balancing stress test).
* incast — n sources to one destination.
* collective traces — produced by repro.collective.algorithms and replayed
  here with message dependencies (a message starts only when its parents
  complete).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Optional

from .events import NetSim


def permutation_pairs(n_hosts: int, seed: int = 0) -> list[tuple[int, int]]:
    """Random derangement: every host sends one flow and receives one."""
    rng = random.Random(seed)
    while True:
        perm = list(range(n_hosts))
        rng.shuffle(perm)
        if all(perm[i] != i for i in range(n_hosts)):
            return [(i, perm[i]) for i in range(n_hosts)]


def run_permutation(sim: NetSim, msg_bytes: float, seed: int = 0,
                    until: float = 1e9) -> dict:
    pairs = permutation_pairs(sim.topo.n_hosts, seed)
    for s, d in pairs:
        sim.add_flow(s, d, msg_bytes)
    sim.run(until=until)
    fcts = [fl.fct for fl in sim.flows.values() if fl.fct is not None]
    unfinished = sum(1 for fl in sim.flows.values() if fl.fct is None)
    return {
        "max_fct": max(fcts) if fcts else float("nan"),
        "avg_fct": sum(fcts) / len(fcts) if fcts else float("nan"),
        "unfinished": unfinished,
        "drops": sim.total_drops,
        "pauses": len(sim.pause_log),
    }


def run_incast(sim: NetSim, fan_in: int, msg_bytes: float, dst: int = 0,
               until: float = 1e9, seed: int = 0) -> dict:
    """fan_in sources (on other ToRs where possible) -> one destination."""
    rng = random.Random(seed)
    candidates = [h for h in range(sim.topo.n_hosts) if h != dst]
    srcs = rng.sample(candidates, min(fan_in, len(candidates)))
    for s in srcs:
        sim.add_flow(s, dst, msg_bytes)
    sim.run(until=until)
    fcts = [fl.fct for fl in sim.flows.values() if fl.fct is not None]
    unfinished = sum(1 for fl in sim.flows.values() if fl.fct is None)
    return {
        "max_fct": max(fcts) if fcts else float("nan"),
        "avg_fct": sum(fcts) / len(fcts) if fcts else float("nan"),
        "unfinished": unfinished,
        "drops": sim.total_drops,
        "pauses": len(sim.pause_log),
    }


# --------------------------------------------------------------------------- #
# Dependency-scheduled message traces (collectives)
# --------------------------------------------------------------------------- #

@dataclass
class TraceMessage:
    """One message of a collective trace with dependency edges."""

    mid: int
    src: int                       # rank (mapped to host via placement)
    dst: int
    size: float
    deps: list[int] = field(default_factory=list)  # message ids
    group: int = 0                 # which collective instance
    started: bool = False
    done: bool = False


class TraceRunner:
    """Replays dependency traces on a NetSim: a message launches when all
    its dependencies have completed (paper Section 4.3 trace semantics)."""

    def __init__(self, sim: NetSim, messages: list[TraceMessage],
                 placement: dict[int, int]):
        self.sim = sim
        self.msgs = {m.mid: m for m in messages}
        self.placement = placement  # rank -> host
        self.children: dict[int, list[int]] = {m.mid: [] for m in messages}
        self.pending_deps = {m.mid: len(m.deps) for m in messages}
        for m in messages:
            for d in m.deps:
                self.children[d].append(m.mid)
        self.flow_to_msg: dict[int, int] = {}
        self.group_done_ts: dict[int, float] = {}
        self.group_msgs: dict[int, int] = {}
        for m in messages:
            self.group_msgs[m.group] = self.group_msgs.get(m.group, 0) + 1
        sim.on_flow_done = self._on_flow_done

    def _launch(self, m: TraceMessage, now: float):
        m.started = True
        fl = self.sim.add_flow(self.placement[m.src], self.placement[m.dst],
                               m.size, start_ts=now, meta=m.mid)
        self.flow_to_msg[fl.id] = m.mid

    def _on_flow_done(self, fl, now: float):
        mid = self.flow_to_msg.get(fl.id)
        if mid is None:
            return
        m = self.msgs[mid]
        m.done = True
        self.group_msgs[m.group] -= 1
        if self.group_msgs[m.group] == 0:
            self.group_done_ts[m.group] = now
        for c in self.children[mid]:
            self.pending_deps[c] -= 1
            if self.pending_deps[c] == 0:
                self._launch(self.msgs[c], now)

    def run(self, until: float = 1e9) -> dict:
        for m in self.msgs.values():
            if self.pending_deps[m.mid] == 0:
                self._launch(m, 0.0)
        self.sim.run(until=until)
        finished = len(self.group_done_ts)
        return {
            "group_fct": dict(self.group_done_ts),
            "max_collective_time": (max(self.group_done_ts.values())
                                    if self.group_done_ts else float("nan")),
            "finished_groups": finished,
            "total_groups": len(self.group_msgs) if self.group_msgs else 0,
            "drops": self.sim.total_drops,
            "pauses": len(self.sim.pause_log),
        }
