"""The ONE experiment API (Section 4.2/4.3): Scenario + RunConfig + run().

Every experiment in the paper's evaluation matrix is a :class:`Scenario` —
topology + network + a list of :class:`Message` records carrying optional
*dependency edges* (``mid/src/dst/size/deps/group``) — executed by a single
entry point against a :class:`RunConfig`:

    >>> res = run(scenario, RunConfig(backend="fabric", protocol="strack"))
    >>> rows = sweep(scenarios, RunConfig(protocol="rocev2", subflows=4))

``RunConfig`` names the backend ("fabric" = the jitted multi-queue
fat-tree in ``fabric.py``, ~1000x faster; "events" = the discrete-event
oracle in ``events.py``), the protocol ("strack" | "rocev2"), the STrack
load-balance mode (adaptive / oblivious / fixed spray), PFC losslessness,
message->sub-flow striping (``subflows=4`` is the paper's tuned 4-QP
RoCEv2), the event-horizon scan (``time_warp``, default on: dead tick
intervals collapse with bit-exact results), trace decimation
(``trace_every``), queue tracing and seeds.  ``sweep()`` takes one config
or a list: data axes (msg sizes, lb_mode, entropy seed) vmap through ONE
cached program; static axes (protocol, subflows, pfc) partition into one
vmapped batch per program shape (docs/performance.md).  Both backends honour dependency
scheduling — a message launches only once all its ``deps`` completed — so
the collective traces of Figs 21-28 run on the fast path too; plain flow
lists are simply the deps-free special case.

Builders cover the evaluation matrix: ``permutation_scenario`` (Figs
8-11), ``incast_scenario`` (Figs 16-20), ``oversub_scenario`` (Figs
12-13), ``linkdown_scenario`` (Figs 14-15) and ``collective_scenario``
(Figs 1-2, 21-28: ring / double-binary-tree / halving-doubling allreduce
and windowed all-to-all via ``repro.collective.algorithms``, multi-job
placement included).  Both backends return the same summary dict
(max_fct / avg_fct / unfinished / drops / pauses, plus group_fct /
max_collective_time / finished_groups / total_groups for grouped traces)
so results are directly comparable — the parity gates in
``tests/test_fabric*.py`` and ``tests/test_collective_fabric.py`` rely on
that.

:class:`TraceRunner` is the event-backend dependency scheduler (also the
parity oracle for the fabric's); ``run_scenario_on_sim`` runs a scenario
on a prebuilt NetSim when custom oracle wiring (queue logs, link
failures) is needed.  The PR 3 deprecation shims are gone — see
docs/experiments.md for the run()/sweep() migration table.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, replace
from typing import Optional, Sequence, Tuple

import numpy as np

from ..core.params import NetworkSpec, make_roce_params
from .events import NetSim
from .fabric import (FabricConfig, _rto_us, run_fabric_trace,
                     run_fabric_trace_batch, summarize)
from .faults import FaultSpec
from .topology import FatTree, full_bisection, oversubscribed, \
    with_link_failures


def permutation_pairs(n_hosts: int, seed: int = 0) -> list[tuple[int, int]]:
    """Random derangement: every host sends one flow and receives one."""
    rng = random.Random(seed)
    while True:
        perm = list(range(n_hosts))
        rng.shuffle(perm)
        if all(perm[i] != i for i in range(n_hosts)):
            return [(i, perm[i]) for i in range(n_hosts)]


# --------------------------------------------------------------------------- #
# Messages + Scenario — one object, both backends
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class Message:
    """One message of a workload trace, with dependency edges.

    ``src``/``dst`` are host ids; ``deps`` lists the ``mid``s that must
    complete before this message may launch (paper Section 4.3 trace
    semantics); ``group`` tags which collective instance the message
    belongs to.  A plain flow is a ``Message`` with no deps.

    ``arrival`` is the earliest tick the message may launch even once its
    deps are met — the open-loop knob the multi-tenant traffic generator
    (``sim/traffic.py``) uses for staggered job starts and Poisson-style
    burst arrivals.  0 (the default) preserves the closed-loop semantics.
    On the events backend it converts to microseconds via the scenario
    network's ``mtu_serialize_us`` (one fabric tick = one MTU slot).
    """

    mid: int
    src: int
    dst: int
    size: float
    deps: Tuple[int, ...] = ()
    group: int = 0
    arrival: int = 0

    def __post_init__(self):
        object.__setattr__(self, "deps", tuple(self.deps))


#: Deprecated alias — collective trace generators historically emitted
#: ``TraceMessage``; the unified API calls them :class:`Message`.
TraceMessage = Message


@dataclass(frozen=True)
class Scenario:
    """A backend-agnostic workload: who sends what, after whom, where."""

    name: str
    topo: FatTree
    net: NetworkSpec
    messages: Tuple[Message, ...]
    #: Optional chaos schedule (sim/faults.py): scheduled link/NIC flaps,
    #: degraded links and seeded corruption, honoured by BOTH backends.
    #: ``RunConfig.faults`` overrides this when set.
    faults: Optional[FaultSpec] = None

    @classmethod
    def from_flows(cls, name: str, topo: FatTree, net: NetworkSpec,
                   flows: Sequence[Tuple[int, int, float]]) -> "Scenario":
        """Wrap a plain [(src, dst, bytes), ...] list (the deps-free case)."""
        return cls(name=name, topo=topo, net=net,
                   messages=tuple(Message(mid=i, src=s, dst=d, size=float(b))
                                  for i, (s, d, b) in enumerate(flows)))

    @property
    def flows(self) -> Tuple[Tuple[int, int, float], ...]:
        """The flow-list view (message sizes, dependency edges dropped)."""
        return tuple((m.src, m.dst, m.size) for m in self.messages)

    @property
    def has_deps(self) -> bool:
        return any(m.deps for m in self.messages)

    @property
    def n_groups(self) -> int:
        return len({m.group for m in self.messages})

    @property
    def is_trace(self) -> bool:
        """True when the scenario carries trace structure (dependency
        edges or several groups) and so reports collective group metrics
        on BOTH backends (TraceRunner scheduling on events)."""
        return self.has_deps or self.n_groups > 1

    def default_ticks(self) -> int:
        """Tick budget for a fabric run: the larger of the worst
        per-destination serialisation and the dependency critical path
        (chained traces serialise whole messages end-to-end, each handoff
        costing a delivery+ack round trip), with convergence margin.

        Each handoff budgets one full base RTT plus a small per-hop
        quantization slack: the per-hop pipeline realizes the RTT in
        whole-tick serialization + propagation stages, so rounding can
        cost a couple of ticks per dependency step."""
        mtu = self.net.mtu_bytes
        rtt_ticks = self.net.base_rtt_us / self.net.mtu_serialize_us + 2
        pkts: dict[int, float] = {}
        per_dst: dict[int, float] = {}
        for m in self.messages:
            pkts[m.mid] = math.ceil(m.size / mtu)
            per_dst[m.dst] = per_dst.get(m.dst, 0.0) + pkts[m.mid]
        bottleneck = max(per_dst.values()) if per_dst else 1.0
        # critical path over the dependency DAG (iterative DFS — edges may
        # point at any mid, not just smaller ones; deps on the current DFS
        # path would be cycles and are skipped rather than looping)
        by_mid = {m.mid: m for m in self.messages}
        depth: dict[int, float] = {}
        visiting: set[int] = set()
        for root in by_mid:
            stack = [root]
            while stack:
                mid = stack[-1]
                if mid in depth:
                    stack.pop()
                    visiting.discard(mid)
                    continue
                visiting.add(mid)
                todo = [d for d in by_mid[mid].deps
                        if d in by_mid and d not in depth
                        and d not in visiting]
                if todo:
                    stack.extend(todo)
                    continue
                stack.pop()
                visiting.discard(mid)
                base = max((depth[d] for d in by_mid[mid].deps
                            if d in depth), default=0.0)
                # an arrival tick can hold a message past its deps: the
                # critical path through it starts no earlier than that
                base = max(base, float(by_mid[mid].arrival))
                depth[mid] = base + pkts[mid] + rtt_ticks
        crit = max(depth.values()) if depth else 1.0
        return int(4 * max(bottleneck, crit) + 30 * rtt_ticks + 1000)


# --------------------------------------------------------------------------- #
# Scenario builders — the paper's evaluation matrix
# --------------------------------------------------------------------------- #

def permutation_scenario(topo: FatTree, msg_bytes: float,
                         net: Optional[NetworkSpec] = None,
                         seed: int = 0) -> Scenario:
    net = net or NetworkSpec()
    pairs = permutation_pairs(topo.n_hosts, seed)
    return Scenario.from_flows(
        f"permutation_{topo.n_hosts}", topo, net,
        [(s, d, float(msg_bytes)) for s, d in pairs])


def incast_scenario(topo: FatTree, fan_in: int, msg_bytes: float,
                    dst: int = 0, net: Optional[NetworkSpec] = None,
                    seed: int = 0) -> Scenario:
    """fan_in sources -> one destination (sampled like the legacy runner)."""
    net = net or NetworkSpec()
    rng = random.Random(seed)
    candidates = [h for h in range(topo.n_hosts) if h != dst]
    srcs = rng.sample(candidates, min(fan_in, len(candidates)))
    return Scenario.from_flows(
        f"incast_{fan_in}to1", topo, net,
        [(s, dst, float(msg_bytes)) for s in srcs])


def oversub_scenario(n_tor: int, hosts_per_tor: int, ratio: int,
                     msg_bytes: float, net: Optional[NetworkSpec] = None,
                     seed: int = 0) -> Scenario:
    topo = oversubscribed(n_tor, hosts_per_tor, ratio)
    sc = permutation_scenario(topo, msg_bytes, net, seed)
    return Scenario(name=f"oversub_{ratio}:1", topo=topo, net=sc.net,
                    messages=sc.messages)


def linkdown_scenario(topo_kw: dict, frac_links_down: float,
                      msg_bytes: float, net: Optional[NetworkSpec] = None,
                      seed: int = 0) -> Scenario:
    """Permutation over an asymmetric (dead-link) full-bisection fabric."""
    base = full_bisection(**topo_kw)
    n_links = base.n_tor * base.n_spine
    n_down = max(1, int(frac_links_down * n_links))
    topo = with_link_failures(base, n_down,
                              n_tors_affected=max(1, base.n_tor // 2),
                              seed=seed)
    sc = permutation_scenario(topo, msg_bytes, net, seed)
    return Scenario(name=f"linkdown_{n_down}", topo=topo, net=sc.net,
                    messages=sc.messages)


def collective_scenario(topo: FatTree, algo: str, n_jobs: int,
                        ranks_per_job: int, collective_bytes: float,
                        net: Optional[NetworkSpec] = None, seed: int = 0,
                        **algo_kw) -> Scenario:
    """Dependency-scheduled collective trace (Figs 1-2, 21-28) as a
    Scenario: ``n_jobs`` instances of ``algo`` (ring / dbt / hd / a2a from
    ``repro.collective.algorithms``), each group randomly placed on the
    cluster; rank ids are resolved to hosts here so the trace runs
    unchanged on either backend.  ``algo_kw`` reaches the generator
    (``chunk=``, ``window=`` for a2a)."""
    from ..collective.algorithms import multi_job  # cycle: algorithms ← us
    net = net or NetworkSpec()
    msgs, placement = multi_job(algo, n_jobs, ranks_per_job, topo.n_hosts,
                                collective_bytes, seed=seed, **algo_kw)
    return Scenario(
        name=f"{algo}_x{n_jobs}r{ranks_per_job}",
        topo=topo, net=net,
        messages=tuple(Message(mid=m.mid, src=placement[m.src],
                               dst=placement[m.dst], size=m.size,
                               deps=tuple(m.deps), group=m.group,
                               arrival=m.arrival)
                       for m in msgs))


# --------------------------------------------------------------------------- #
# RunConfig + run()/sweep(): the single entry point, both backends
# --------------------------------------------------------------------------- #

BACKENDS = ("fabric", "events")
PROTOCOLS = ("strack", "rocev2")
LB_MODES = ("adaptive", "oblivious", "fixed")
ACK_PATHS = ("perhop", "folded")
KERNEL_BACKENDS = ("jnp", "pallas", "pallas_interpret")


@dataclass(frozen=True)
class RunConfig:
    """Everything about HOW a scenario runs (the scenario says WHAT)."""

    backend: str = "fabric"          # fabric (jitted) | events (oracle)
    protocol: str = "strack"         # strack | rocev2
    lb_mode: str = "adaptive"        # STrack spray: adaptive|oblivious|fixed
    pfc: Optional[bool] = None       # None -> lossless iff rocev2
    max_paths: int = 64              # STrack entropy space
    subflows: int = 1                # message striping (4 = tuned RoCEv2)
    n_ticks: Optional[int] = None    # fabric horizon (None -> default_ticks)
    switch_buffer_bytes: Optional[float] = None  # None -> backend default
    roce_entropy_seed: Optional[int] = None      # align QP entropy w/ oracle
    # --- per-hop latency model ------------------------------------------
    # "perhop" (default): packets accrue serialization + propagation at
    # every queue stage and ACKs return over their flow's reverse path, so
    # the uncongested RTT realizes net.base_rtt_us on BOTH backends (the
    # events oracle always runs this model).  "folded" restores the
    # fabric's legacy single-constant return pipe (fabric-only knob).
    ack_path: str = "perhop"
    # Per-link propagation override (us); None derives it from the
    # scenario's NetworkSpec (net.hop_prop_effective_us).  Honoured by
    # both backends.
    hop_prop_us: Optional[float] = None
    # Fabric: ticks a PFC pause/resume frame takes to reach the upstream
    # queue (None -> one hop of propagation; the oracle always delays
    # pause frames by its propagation).
    pfc_delay_ticks: Optional[int] = None
    # Event-horizon scan (fabric): skip provably-dead tick intervals in one
    # scan trip.  Bit-identical completion ticks / drops / pauses vs dense
    # ticking (tests/test_timewarp.py); set False to force dense ticking.
    time_warp: bool = True
    # Per-tick trace decimation (fabric): 0 = no trace (summaries come
    # from the exact final carry — the default, so scan-carry memory no
    # longer scales with n_ticks), k>=1 = snapshot every k ticks (forces
    # dense ticking).
    trace_every: int = 0
    trace_queues: bool = False       # fabric: per-tick queue-depth settle
    qdelay_threshold_us: float = 8.0
    # Fabric active set: lane count for the NIC/timer stage (None = every
    # flow is a lane).  Caps the per-tick cost at O(active_cap) instead of
    # O(n_flows) for traces where most flows are dep-gated or already
    # done; the program RAISES post-run if the cap was ever exceeded.
    # Requires the no-trace path (trace_every=0, trace_queues off).
    active_cap: Optional[int] = None
    # Fabric sharding: partition the program over this many devices with
    # shard_map (queues by switch block, flows by block; the inter-pod hop
    # is an explicit all_gather exchange).  0/1 = single-device.  On CPU,
    # force a device mesh with XLA_FLAGS=--xla_force_host_platform_
    # device_count=N.  Bit-exact vs unsharded; requires trace_every=0.
    shard: int = 0
    # Fabric kernel backend for the scan body's hot stages: "jnp"
    # (inline, XLA-fused — the default), "pallas" (compiled Pallas
    # kernels; real TPU/GPU) or "pallas_interpret" (Pallas interpret
    # mode, runs anywhere incl. CPU CI).  All three are bit-exact
    # (tests/test_fabric_kernels.py + the fuzz suite's kernel leg);
    # single-device only (shard <= 1).
    kernel_backend: str = "jnp"
    # Chaos schedule (sim/faults.py): time-varying link/NIC flaps,
    # degraded links, seeded corruption.  Overrides ``Scenario.faults``
    # when set; faults are program *data* on the fabric backend (one
    # compiled program serves every schedule of the same shape).  When
    # ``n_ticks`` is None the default horizon is extended past the last
    # fault edge so recovery has room to complete.
    faults: Optional[FaultSpec] = None
    seed: int = 1234                 # events-backend rng seed
    until: float = 1e9               # events-backend horizon (us)

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected one of {BACKENDS}")
        if self.protocol not in PROTOCOLS:
            raise ValueError(f"unknown protocol {self.protocol!r}; "
                             f"expected one of {PROTOCOLS}")
        if self.lb_mode not in LB_MODES:
            raise ValueError(f"unknown lb_mode {self.lb_mode!r}; "
                             f"expected one of {LB_MODES}")
        if self.ack_path not in ACK_PATHS:
            raise ValueError(f"unknown ack_path {self.ack_path!r}; "
                             f"expected one of {ACK_PATHS}")
        if self.trace_every < 0:
            raise ValueError(
                f"trace_every must be >= 0, got {self.trace_every}")
        if self.active_cap is not None and self.active_cap <= 0:
            raise ValueError(
                f"active_cap must be positive, got {self.active_cap}")
        if self.shard < 0:
            raise ValueError(f"shard must be >= 0, got {self.shard}")
        if self.kernel_backend not in KERNEL_BACKENDS:
            raise ValueError(
                f"unknown kernel_backend {self.kernel_backend!r}; "
                f"expected one of {KERNEL_BACKENDS}")
        if self.kernel_backend != "jnp" and self.shard > 1:
            raise ValueError(
                f"kernel_backend={self.kernel_backend!r} requires "
                f"shard <= 1 (the sharded program keeps its inline jnp "
                f"stages)")
        if (self.active_cap or self.shard > 1) and (
                self.trace_every or self.trace_queues):
            raise ValueError(
                "active_cap/shard need the no-trace path "
                "(trace_every=0, trace_queues=False)")


def run(sc: Scenario, cfg: RunConfig = RunConfig()) -> dict:
    """Run one scenario under one config; oracle-comparable summary dict.

    Dispatches on ``cfg.backend``: the jitted fabric honours dependency
    gating and sub-flow striping inside its ``lax.scan``; the event oracle
    uses :class:`TraceRunner` (deps) or plain flow addition (no deps).
    """
    if cfg.backend == "fabric":
        return _run_fabric_backend(sc, cfg)
    return _run_events_backend(sc, cfg)


def sweep(scenarios: Sequence[Scenario],
          cfg=RunConfig()) -> list:
    """Run a batch of same-structure scenarios under one config — or under
    a matching list of configs (a multi-axis sweep).

    ``cfg`` is a single :class:`RunConfig` (applied to every scenario) or
    a sequence of them.  Lengths must match, or either side may be length
    1 and is broadcast — so ``sweep([sc], [cfg_a, cfg_b, cfg_c])`` sweeps
    config axes over one scenario and ``sweep(seeds, cfg)`` sweeps seeds
    under one config.

    On the fabric backend, everything that is *data* to the compiled
    program is vmapped through ONE jitted XLA call per program shape:
    message src/dst/sizes (e.g. msg-size or placement-seed axes),
    ``lb_mode`` (a traced scalar) and ``roce_entropy_seed``.  Axes that
    change the program itself (protocol, pfc, ``subflows``, n_ticks,
    buffer sizes, time_warp) partition the sweep into one vmapped batch
    per group — each served by the program cache, so repeated sweeps
    compile nothing.  All scenarios must share a topology, network and
    message/dependency structure (different src/dst/size patterns are
    fine: that is the point).  On the events backend it simply loops.
    Returns one summary dict per (scenario, config) pair, input order.
    """
    if not scenarios:
        raise ValueError("sweep() needs at least one scenario")
    scenarios = list(scenarios)
    cfgs = list(cfg) if isinstance(cfg, (list, tuple)) else [cfg]
    if not cfgs:
        raise ValueError("sweep() needs at least one config")
    if len(scenarios) == 1 and len(cfgs) > 1:
        scenarios = scenarios * len(cfgs)
    if len(cfgs) == 1 and len(scenarios) > 1:
        cfgs = cfgs * len(scenarios)
    if len(cfgs) != len(scenarios):
        raise ValueError(
            f"sweep() got {len(scenarios)} scenarios and {len(cfgs)} "
            f"configs; lengths must match, or either side must be 1")
    # the shared-structure requirement exists so one vmapped program can
    # serve the batch — it only binds the fabric-backend entries (the
    # events oracle simply loops and takes any mix of scenarios)
    fabric_ix = [i for i, rc in enumerate(cfgs) if rc.backend == "fabric"]
    sc0 = scenarios[fabric_ix[0]] if fabric_ix else None
    for i in fabric_ix[1:]:
        sc = scenarios[i]
        if sc.topo != sc0.topo:
            raise ValueError(
                f"sweep() scenarios must share a topology: field 'topo' of "
                f"{sc.name!r} is {sc.topo}, of {sc0.name!r} is {sc0.topo}")
        if sc.net != sc0.net:
            raise ValueError(
                f"sweep() scenarios must share a network: field 'net' of "
                f"{sc.name!r} is {sc.net}, of {sc0.name!r} is {sc0.net}")
        if len(sc.messages) != len(sc0.messages):
            raise ValueError(
                f"sweep() scenarios must share the message structure: "
                f"field 'messages' of {sc.name!r} has {len(sc.messages)} "
                f"entries, of {sc0.name!r} has {len(sc0.messages)}")
        structure = [(m.deps, m.group) for m in sc.messages]
        structure0 = [(m.deps, m.group) for m in sc0.messages]
        if structure != structure0:
            bad = next(i for i, (a, b) in
                       enumerate(zip(structure, structure0)) if a != b)
            raise ValueError(
                f"sweep() scenarios must share the dependency structure: "
                f"field 'messages[{bad}].deps/group' of {sc.name!r} is "
                f"{structure[bad]}, of {sc0.name!r} is {structure0[bad]}")
    out: list = [None] * len(cfgs)
    # group fabric pairs by everything static to the program; lb_mode and
    # entropy seed are data axes within a group
    groups: dict = {}
    for i, (sc, rc) in enumerate(zip(scenarios, cfgs)):
        if rc.backend != "fabric":
            out[i] = run(sc, rc)
            continue
        fcfg = _fabric_cfg(sc, rc)
        key = (replace(fcfg, lb_mode="adaptive", roce_entropy_seed=None),
               rc.n_ticks, rc.trace_queues)
        groups.setdefault(key, []).append(i)
    for idxs in groups.values():
        rc0 = cfgs[idxs[0]]
        fcfg0 = _fabric_cfg(scenarios[idxs[0]], rc0)
        ticks = rc0.n_ticks or max(_scenario_ticks(scenarios[i], cfgs[i])
                                   for i in idxs)
        _, per_entry = run_fabric_trace_batch(
            scenarios[idxs[0]].topo,
            [scenarios[i].messages for i in idxs], ticks, fcfg0,
            lb_modes=[cfgs[i].lb_mode for i in idxs],
            entropy_seeds=[cfgs[i].roce_entropy_seed for i in idxs])
        for i, metrics in zip(idxs, per_entry):
            out[i] = _fabric_summary(scenarios[i], cfgs[i], metrics)
    return out


# --------------------------------------------------------------------------- #
# Backend plumbing
# --------------------------------------------------------------------------- #

def _effective_faults(sc: Scenario, cfg: RunConfig) -> Optional[FaultSpec]:
    """RunConfig.faults wins over Scenario.faults (config says HOW)."""
    return cfg.faults if cfg.faults is not None else sc.faults


def _scenario_ticks(sc: Scenario, cfg: RunConfig) -> int:
    """Fabric horizon: explicit n_ticks, else default_ticks() extended by
    the fault schedule — a flap that outlives the clean-run horizon needs
    the window itself, a few RTOs of loss recovery (go-back-N may need a
    full timeout per loss burst) and the clean drain budget after the
    last edge.  Time-warp makes the generous margin nearly free: dead
    tick intervals collapse in one scan trip."""
    if cfg.n_ticks is not None:
        return cfg.n_ticks
    ticks = sc.default_ticks()
    fs = _effective_faults(sc, cfg)
    if fs is not None and fs.last_edge > 0:
        rto_ticks = math.ceil(_rto_us(_fabric_cfg(sc, cfg))
                              / sc.net.mtu_serialize_us)
        ticks = max(ticks, fs.last_edge + 4 * rto_ticks + ticks)
    return ticks


def _fabric_cfg(sc: Scenario, cfg: RunConfig) -> FabricConfig:
    time_warp, trace_every = cfg.time_warp, cfg.trace_every
    if cfg.trace_queues:
        trace_every = trace_every or 1
    if trace_every:
        # any per-tick trace (queue settle or an explicit trace_every=k)
        # needs dense ticking: a data-dependent trip count can't stack one
        time_warp = False
    kw = dict(net=sc.net, max_paths=cfg.max_paths, lb_mode=cfg.lb_mode,
              protocol=cfg.protocol, pfc=cfg.pfc, subflows=cfg.subflows,
              roce_entropy_seed=cfg.roce_entropy_seed,
              ack_path=cfg.ack_path, hop_prop_us=cfg.hop_prop_us,
              pfc_delay_ticks=cfg.pfc_delay_ticks,
              time_warp=time_warp, trace_every=trace_every,
              active_cap=cfg.active_cap, shard=cfg.shard,
              kernel_backend=cfg.kernel_backend,
              faults=_effective_faults(sc, cfg))
    if cfg.switch_buffer_bytes is not None:
        kw["switch_buffer_bytes"] = cfg.switch_buffer_bytes
    return FabricConfig(**kw)


def _queue_settle_us(metrics: dict, threshold_us: float) -> float:
    """Last simulated time any fabric queue's delay (depth x tick) exceeded
    ``threshold_us`` — the fabric analogue of the event backend's
    queue-delay logs (Fig 8 settling time).  With a decimated trace
    (``trace_every=k``) rows sample block ends, so the settle time is
    quantised to k ticks."""
    q = np.asarray(metrics["qsize"], dtype=float)      # [rows, Q]
    tick = metrics["tick_us"]                          # per-pkt delay unit
    k = max(1, metrics.get("trace_every", 1))          # row -> tick stride
    over = np.nonzero((q * tick > threshold_us).any(axis=1))[0]
    return float((over[-1] + 1) * k * tick) if len(over) else 0.0


def _fabric_summary(sc: Scenario, cfg: RunConfig, metrics: dict) -> dict:
    out = summarize(metrics)
    out["backend"] = "fabric"
    out["name"] = sc.name
    out["protocol"] = cfg.protocol
    out["lb_mode"] = cfg.lb_mode
    out["subflows"] = cfg.subflows
    if "warp_trips" in metrics:  # event-horizon diagnostics
        out["warp_trips"] = int(np.asarray(metrics["warp_trips"]))
        out["end_tick"] = int(np.asarray(metrics["end_tick"]))
    if cfg.trace_queues:
        out["queue_settle_us"] = _queue_settle_us(metrics,
                                                  cfg.qdelay_threshold_us)
    return out


def _run_fabric_backend(sc: Scenario, cfg: RunConfig) -> dict:
    fcfg = _fabric_cfg(sc, cfg)
    _, metrics = run_fabric_trace(sc.topo, sc.messages,
                                  _scenario_ticks(sc, cfg), fcfg)
    return _fabric_summary(sc, cfg, metrics)


def _events_sim(sc: Scenario, cfg: RunConfig, **netsim_kw) -> NetSim:
    if cfg.hop_prop_us is not None:
        # the oracle reads its per-link propagation from the NetworkSpec;
        # a RunConfig override rides in on a replaced spec
        sc = replace(sc, net=replace(sc.net, hop_prop_us=cfg.hop_prop_us))
    kw = dict(seed=cfg.seed)
    if cfg.switch_buffer_bytes is not None:
        kw["switch_buffer_bytes"] = cfg.switch_buffer_bytes
    fs = _effective_faults(sc, cfg)
    if fs is not None:
        kw["faults"] = fs
    kw.update(netsim_kw)
    if cfg.protocol == "strack":
        if cfg.lb_mode == "fixed":
            raise ValueError("lb_mode='fixed' (single-path pinning) only "
                             "exists on the fabric backend")
        # a caller-provided kwarg (legacy shim path) wins over lb_mode
        obl = kw.pop("oblivious_spray", cfg.lb_mode == "oblivious")
        return NetSim(sc.topo, sc.net, transport="strack",
                      oblivious_spray=obl, **kw)
    rp = kw.pop("roce_params",
                make_roce_params(sc.net, qps_per_conn=cfg.subflows))
    return NetSim(sc.topo, sc.net, transport="roce", roce_params=rp, **kw)


def _run_events_backend(sc: Scenario, cfg: RunConfig,
                        **netsim_kw) -> dict:
    sim = _events_sim(sc, cfg, **netsim_kw)
    return run_scenario_on_sim(sim, sc, until=cfg.until)


def _summarize_sim(sim: NetSim) -> dict:
    fcts = [fl.fct for fl in sim.flows.values() if fl.fct is not None]
    return {
        "max_fct": max(fcts) if fcts else float("nan"),
        "avg_fct": sum(fcts) / len(fcts) if fcts else float("nan"),
        "unfinished": sum(1 for fl in sim.flows.values() if fl.fct is None),
        "drops": sim.total_drops,
        "pauses": len(sim.pause_log),
        # uniform recovery/fault schema (same keys as fabric summarize()):
        # the oracle counts fault losses directly; per-protocol recovery
        # counters live inside the ref engines and are reported as 0 here
        "retransmits": 0,
        "rto_fires": 0,
        "sack_recoveries": 0,
        "gbn_rewinds": 0,
        "blackholed_pkts": getattr(sim, "blackholed_pkts", 0),
        "corrupt_drops": getattr(sim, "corrupt_drops", 0),
    }


# --------------------------------------------------------------------------- #
# TraceRunner: the event-backend dependency scheduler (fabric parity oracle)
# --------------------------------------------------------------------------- #

class TraceRunner:
    """Replays dependency traces on a NetSim: a message launches when all
    its dependencies have completed (paper Section 4.3 trace semantics).

    ``placement`` maps message src/dst ids to hosts (identity when the
    messages already carry host ids, as ``Scenario.messages`` do)."""

    def __init__(self, sim: NetSim, messages: list,
                 placement: dict[int, int]):
        self.sim = sim
        self.msgs = {m.mid: m for m in messages}
        self.placement = placement  # rank -> host
        self.children: dict[int, list[int]] = {m.mid: [] for m in messages}
        self.pending_deps = {m.mid: len(m.deps) for m in messages}
        for m in messages:
            for d in m.deps:
                self.children[d].append(m.mid)
        self.flow_to_msg: dict[int, int] = {}
        self.done: set[int] = set()
        self.group_done_ts: dict[int, float] = {}
        self.group_msgs: dict[int, int] = {}
        for m in messages:
            self.group_msgs[m.group] = self.group_msgs.get(m.group, 0) + 1
        sim.on_flow_done = self._on_flow_done

    def _launch(self, m: Message, now: float):
        # honour the open-loop arrival tick: one fabric tick = one MTU
        # serialisation slot, so arrival converts via mtu_serialize_us
        start = max(now, m.arrival * self.sim.net.mtu_serialize_us)
        fl = self.sim.add_flow(self.placement[m.src], self.placement[m.dst],
                               m.size, start_ts=start, meta=m.mid)
        self.flow_to_msg[fl.id] = m.mid

    def _on_flow_done(self, fl, now: float):
        mid = self.flow_to_msg.get(fl.id)
        if mid is None:
            return
        m = self.msgs[mid]
        self.done.add(mid)
        self.group_msgs[m.group] -= 1
        if self.group_msgs[m.group] == 0:
            self.group_done_ts[m.group] = now
        for c in self.children[mid]:
            self.pending_deps[c] -= 1
            if self.pending_deps[c] == 0:
                self._launch(self.msgs[c], now)

    def run(self, until: float = 1e9) -> dict:
        for m in self.msgs.values():
            if self.pending_deps[m.mid] == 0:
                self._launch(m, 0.0)
        self.sim.run(until=until)
        finished = len(self.group_done_ts)
        msg_fct = {mid: fl.fct for fl in self.sim.flows.values()
                   if (mid := self.flow_to_msg.get(fl.id)) is not None
                   and fl.fct is not None}
        return {
            "group_fct": dict(self.group_done_ts),
            "max_collective_time": (max(self.group_done_ts.values())
                                    if self.group_done_ts else float("nan")),
            "finished_groups": finished,
            "total_groups": len(self.group_msgs) if self.group_msgs else 0,
            "drops": self.sim.total_drops,
            "pauses": len(self.sim.pause_log),
            "msg_fct": msg_fct,
        }


# --------------------------------------------------------------------------- #
# Prebuilt-sim entry point (custom oracle wiring: queue logs, failures)
# --------------------------------------------------------------------------- #

def run_scenario_on_sim(sim: NetSim, sc: Scenario,
                        until: float = 1e9) -> dict:
    """Run a scenario on a prebuilt NetSim (custom params / queue logging).

    Honours dependency edges via :class:`TraceRunner`."""
    if sc.is_trace:
        placement = {h: h for m in sc.messages for h in (m.src, m.dst)}
        res = TraceRunner(sim, list(sc.messages), placement).run(until=until)
        out = {**_summarize_sim(sim), **res}
    else:
        for m in sc.messages:
            sim.add_flow(m.src, m.dst, m.size,
                         start_ts=m.arrival * sim.net.mtu_serialize_us)
        sim.run(until=until)
        out = _summarize_sim(sim)
    out["backend"] = "events"
    out["name"] = sc.name
    return out
