"""Workload generators driving the simulators (Section 4.2/4.3).

One scenario API, two backends.  A :class:`Scenario` is a plain config
object — topology + network + an explicit flow list — that runs unchanged
on either simulator:

* ``run_on_fabric``  — the jitted multi-queue fat-tree (``fabric.py``),
  running BOTH protocols: STrack (adaptive / oblivious / fixed-path spray)
  and RoCEv2 (DCQCN + go-back-N, with or without PFC), ~1000x faster;
  ``run_seed_sweep_on_fabric`` vmaps a batch of same-shape scenarios
  (e.g. N seeds of one workload) through a single jitted program;
* ``run_on_events`` — the discrete-event oracle (``events.py``), used for
  parity testing plus dependency-scheduled collective traces via
  :class:`TraceRunner`.

Builders cover the paper's evaluation matrix: ``permutation_scenario``
(Figs 8-11), ``incast_scenario`` (Figs 16-20), ``oversub_scenario``
(Figs 12-13) and ``linkdown_scenario`` (Figs 14-15).  Both runners return
the same summary dict (max_fct / avg_fct / unfinished / drops / pauses) so
results are directly comparable — the parity tests in
``tests/test_fabric.py`` and ``tests/test_fabric_roce.py`` rely on that.

Legacy entry points ``run_permutation(sim, ...)`` / ``run_incast(sim, ...)``
keep working on a prebuilt :class:`NetSim`.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

from ..core.params import NetworkSpec
from .events import NetSim
from .topology import FatTree, full_bisection, oversubscribed, \
    with_link_failures


def permutation_pairs(n_hosts: int, seed: int = 0) -> list[tuple[int, int]]:
    """Random derangement: every host sends one flow and receives one."""
    rng = random.Random(seed)
    while True:
        perm = list(range(n_hosts))
        rng.shuffle(perm)
        if all(perm[i] != i for i in range(n_hosts)):
            return [(i, perm[i]) for i in range(n_hosts)]


# --------------------------------------------------------------------------- #
# Scenario configs — one object, both backends
# --------------------------------------------------------------------------- #

@dataclass(frozen=True)
class Scenario:
    """A backend-agnostic workload: who sends how much over which fabric."""

    name: str
    topo: FatTree
    net: NetworkSpec
    flows: Tuple[Tuple[int, int, float], ...]  # (src, dst, msg_bytes)

    def default_ticks(self) -> int:
        """Ticks for a fabric run: worst bottleneck serialisation x margin."""
        mtu = self.net.mtu_bytes
        per_dst: dict[int, float] = {}
        for _, d, b in self.flows:
            per_dst[d] = per_dst.get(d, 0.0) + math.ceil(b / mtu)
        bottleneck = max(per_dst.values()) if per_dst else 1.0
        rtt_ticks = self.net.base_rtt_us / self.net.mtu_serialize_us
        return int(4 * bottleneck + 30 * rtt_ticks + 1000)


def permutation_scenario(topo: FatTree, msg_bytes: float,
                         net: Optional[NetworkSpec] = None,
                         seed: int = 0) -> Scenario:
    net = net or NetworkSpec()
    pairs = permutation_pairs(topo.n_hosts, seed)
    return Scenario(name=f"permutation_{topo.n_hosts}", topo=topo, net=net,
                    flows=tuple((s, d, float(msg_bytes)) for s, d in pairs))


def incast_scenario(topo: FatTree, fan_in: int, msg_bytes: float,
                    dst: int = 0, net: Optional[NetworkSpec] = None,
                    seed: int = 0) -> Scenario:
    """fan_in sources -> one destination (sampled like the legacy runner)."""
    net = net or NetworkSpec()
    rng = random.Random(seed)
    candidates = [h for h in range(topo.n_hosts) if h != dst]
    srcs = rng.sample(candidates, min(fan_in, len(candidates)))
    return Scenario(name=f"incast_{fan_in}to1", topo=topo, net=net,
                    flows=tuple((s, dst, float(msg_bytes)) for s in srcs))


def oversub_scenario(n_tor: int, hosts_per_tor: int, ratio: int,
                     msg_bytes: float, net: Optional[NetworkSpec] = None,
                     seed: int = 0) -> Scenario:
    topo = oversubscribed(n_tor, hosts_per_tor, ratio)
    sc = permutation_scenario(topo, msg_bytes, net, seed)
    return Scenario(name=f"oversub_{ratio}:1", topo=topo, net=sc.net,
                    flows=sc.flows)


def linkdown_scenario(topo_kw: dict, frac_links_down: float,
                      msg_bytes: float, net: Optional[NetworkSpec] = None,
                      seed: int = 0) -> Scenario:
    """Permutation over an asymmetric (dead-link) full-bisection fabric."""
    base = full_bisection(**topo_kw)
    n_links = base.n_tor * base.n_spine
    n_down = max(1, int(frac_links_down * n_links))
    topo = with_link_failures(base, n_down,
                              n_tors_affected=max(1, base.n_tor // 2),
                              seed=seed)
    sc = permutation_scenario(topo, msg_bytes, net, seed)
    return Scenario(name=f"linkdown_{n_down}", topo=topo, net=sc.net,
                    flows=sc.flows)


# --------------------------------------------------------------------------- #
# Backend runners
# --------------------------------------------------------------------------- #

def _fabric_cfg(sc: Scenario, lb_mode: str, max_paths: int, protocol: str,
                pfc: Optional[bool], switch_buffer_bytes: Optional[float],
                roce_entropy_seed: Optional[int]):
    from .fabric import FabricConfig
    kw = dict(net=sc.net, max_paths=max_paths, lb_mode=lb_mode,
              protocol=protocol, pfc=pfc,
              roce_entropy_seed=roce_entropy_seed)
    if switch_buffer_bytes is not None:
        kw["switch_buffer_bytes"] = switch_buffer_bytes
    return FabricConfig(**kw)


def _queue_settle_us(metrics: dict, threshold_us: float) -> float:
    """Last simulated time any fabric queue's delay (depth x tick) exceeded
    ``threshold_us`` — the fabric analogue of the event backend's
    queue-delay logs (Fig 8 settling time)."""
    import numpy as np
    q = np.asarray(metrics["qsize"], dtype=float)      # [ticks, Q]
    tick = metrics["tick_us"]
    over = np.nonzero((q * tick > threshold_us).any(axis=1))[0]
    return float((over[-1] + 1) * tick) if len(over) else 0.0


def run_on_fabric(sc: Scenario, n_ticks: Optional[int] = None,
                  lb_mode: str = "adaptive", max_paths: int = 64,
                  protocol: str = "strack", pfc: Optional[bool] = None,
                  switch_buffer_bytes: Optional[float] = None,
                  roce_entropy_seed: Optional[int] = None,
                  trace_queues: bool = False,
                  qdelay_threshold_us: float = 8.0) -> dict:
    """Run a scenario on the jitted fat-tree; event-oracle-style summary.

    ``protocol`` selects the transport ("strack" | "rocev2"); ``pfc`` makes
    the queues lossless (defaults to on for rocev2, off for strack).  With
    ``trace_queues`` the summary gains ``queue_settle_us`` derived from the
    per-tick queue-depth traces.
    """
    from .fabric import run_fabric, summarize
    cfg = _fabric_cfg(sc, lb_mode, max_paths, protocol, pfc,
                      switch_buffer_bytes, roce_entropy_seed)
    _, metrics = run_fabric(sc.topo, sc.flows,
                            n_ticks or sc.default_ticks(), cfg)
    out = summarize(metrics)
    out["backend"] = "fabric"
    if trace_queues:
        out["queue_settle_us"] = _queue_settle_us(metrics,
                                                  qdelay_threshold_us)
    return out


def run_seed_sweep_on_fabric(scenarios: Sequence[Scenario],
                             n_ticks: Optional[int] = None,
                             lb_mode: str = "adaptive", max_paths: int = 64,
                             protocol: str = "strack",
                             pfc: Optional[bool] = None,
                             switch_buffer_bytes: Optional[float] = None,
                             roce_entropy_seed: Optional[int] = None,
                             trace_queues: bool = False,
                             qdelay_threshold_us: float = 8.0) -> list:
    """Batch same-shape scenarios (seeds of one workload) into ONE vmapped
    jit of the fabric — amortizing compile and pipelining the sweep.

    All scenarios must share topology, network and flow count (different
    src/dst/size patterns are fine — that is the point).  Returns one
    summary dict per scenario, in order.
    """
    from .fabric import run_fabric_batch, summarize
    assert scenarios, "need at least one scenario"
    sc0 = scenarios[0]
    for sc in scenarios[1:]:
        assert sc.topo == sc0.topo and sc.net == sc0.net, \
            "seed sweep requires a shared topology and network"
    cfg = _fabric_cfg(sc0, lb_mode, max_paths, protocol, pfc,
                      switch_buffer_bytes, roce_entropy_seed)
    ticks = n_ticks or max(sc.default_ticks() for sc in scenarios)
    _, per_seed = run_fabric_batch(sc0.topo, [sc.flows for sc in scenarios],
                                   ticks, cfg)
    outs = []
    for sc, metrics in zip(scenarios, per_seed):
        out = summarize(metrics)
        out["backend"] = "fabric"
        out["name"] = sc.name
        if trace_queues:
            out["queue_settle_us"] = _queue_settle_us(metrics,
                                                      qdelay_threshold_us)
        outs.append(out)
    return outs


def run_on_events(sc: Scenario, transport: str = "strack",
                  until: float = 1e9, **netsim_kw) -> dict:
    """Run the same scenario on the discrete-event oracle."""
    sim = NetSim(sc.topo, sc.net, transport=transport, **netsim_kw)
    return run_scenario_on_sim(sim, sc, until=until)


def run_scenario_on_sim(sim: NetSim, sc: Scenario,
                        until: float = 1e9) -> dict:
    """Run a scenario on a prebuilt NetSim (custom params / queue logging)."""
    for s, d, b in sc.flows:
        sim.add_flow(s, d, b)
    sim.run(until=until)
    out = _summarize_sim(sim)
    out["backend"] = "events"
    return out


def _summarize_sim(sim: NetSim) -> dict:
    fcts = [fl.fct for fl in sim.flows.values() if fl.fct is not None]
    return {
        "max_fct": max(fcts) if fcts else float("nan"),
        "avg_fct": sum(fcts) / len(fcts) if fcts else float("nan"),
        "unfinished": sum(1 for fl in sim.flows.values() if fl.fct is None),
        "drops": sim.total_drops,
        "pauses": len(sim.pause_log),
    }


# --------------------------------------------------------------------------- #
# Legacy NetSim entry points (benchmarks/incast.py, collectives, examples)
# --------------------------------------------------------------------------- #

def run_permutation(sim: NetSim, msg_bytes: float, seed: int = 0,
                    until: float = 1e9) -> dict:
    pairs = permutation_pairs(sim.topo.n_hosts, seed)
    for s, d in pairs:
        sim.add_flow(s, d, msg_bytes)
    sim.run(until=until)
    return _summarize_sim(sim)


def run_incast(sim: NetSim, fan_in: int, msg_bytes: float, dst: int = 0,
               until: float = 1e9, seed: int = 0) -> dict:
    """fan_in sources (on other ToRs where possible) -> one destination."""
    sc = incast_scenario(sim.topo, fan_in, msg_bytes, dst=dst, seed=seed,
                         net=sim.net)
    for s, d, b in sc.flows:
        sim.add_flow(s, d, b)
    sim.run(until=until)
    return _summarize_sim(sim)


# --------------------------------------------------------------------------- #
# Dependency-scheduled message traces (collectives) — events backend only
# --------------------------------------------------------------------------- #

@dataclass
class TraceMessage:
    """One message of a collective trace with dependency edges."""

    mid: int
    src: int                       # rank (mapped to host via placement)
    dst: int
    size: float
    deps: list[int] = field(default_factory=list)  # message ids
    group: int = 0                 # which collective instance
    started: bool = False
    done: bool = False


class TraceRunner:
    """Replays dependency traces on a NetSim: a message launches when all
    its dependencies have completed (paper Section 4.3 trace semantics)."""

    def __init__(self, sim: NetSim, messages: list[TraceMessage],
                 placement: dict[int, int]):
        self.sim = sim
        self.msgs = {m.mid: m for m in messages}
        self.placement = placement  # rank -> host
        self.children: dict[int, list[int]] = {m.mid: [] for m in messages}
        self.pending_deps = {m.mid: len(m.deps) for m in messages}
        for m in messages:
            for d in m.deps:
                self.children[d].append(m.mid)
        self.flow_to_msg: dict[int, int] = {}
        self.group_done_ts: dict[int, float] = {}
        self.group_msgs: dict[int, int] = {}
        for m in messages:
            self.group_msgs[m.group] = self.group_msgs.get(m.group, 0) + 1
        sim.on_flow_done = self._on_flow_done

    def _launch(self, m: TraceMessage, now: float):
        m.started = True
        fl = self.sim.add_flow(self.placement[m.src], self.placement[m.dst],
                               m.size, start_ts=now, meta=m.mid)
        self.flow_to_msg[fl.id] = m.mid

    def _on_flow_done(self, fl, now: float):
        mid = self.flow_to_msg.get(fl.id)
        if mid is None:
            return
        m = self.msgs[mid]
        m.done = True
        self.group_msgs[m.group] -= 1
        if self.group_msgs[m.group] == 0:
            self.group_done_ts[m.group] = now
        for c in self.children[mid]:
            self.pending_deps[c] -= 1
            if self.pending_deps[c] == 0:
                self._launch(self.msgs[c], now)

    def run(self, until: float = 1e9) -> dict:
        for m in self.msgs.values():
            if self.pending_deps[m.mid] == 0:
                self._launch(m, 0.0)
        self.sim.run(until=until)
        finished = len(self.group_done_ts)
        return {
            "group_fct": dict(self.group_done_ts),
            "max_collective_time": (max(self.group_done_ts.values())
                                    if self.group_done_ts else float("nan")),
            "finished_groups": finished,
            "total_groups": len(self.group_msgs) if self.group_msgs else 0,
            "drops": self.sim.total_drops,
            "pauses": len(self.sim.pause_log),
        }
